//! Table 10 — the third lever: model architecture as a scenario axis.
//!
//! The paper's Table 2 row pair (Llama-3.1-70B dense at 7.41 tok/W vs
//! Qwen3-235B-A22B weight-streaming at 37.82 tok/W on H100, a 5.1×
//! edge) treats architecture as a fixed property of the workload. With
//! the model axis ([`crate::fleet::profile::ModelAxis`]) it is a lever
//! next to routing and generation: this table sweeps context 4K→64K per
//! architecture on the calibrated H100 fleet profile and answers two
//! questions the paper leaves open — does the 1/W slope survive weight
//! streaming (the `halving` column: tok/W(2L)/tok/W(L) per step of the
//! context ladder), and how much of the MoE edge does a realistic
//! 10 ms all-to-all dispatch erode (§3.2's caveat, quantified via
//! [`crate::roofline::moe::dispatch_erosion`])?

use crate::fleet::profile::{GpuProfile, ModelAxis, PowerAccounting};
use crate::model::spec::{LLAMA31_70B, QWEN3_235B_A22B};
use crate::power::{profiles, Gpu};
use crate::results::{Cell, Column, RowSet};
use crate::roofline::moe::dispatch_erosion;
use crate::tokeconomy::operating_point;

/// Context ladder, the paper's Table 1 sweep range.
pub const CONTEXTS: [u32; 5] = [4096, 8192, 16384, 32768, 65536];

/// The three architectures on the axis, dense first (the baseline the
/// `×dense` column divides by).
pub fn models() -> [ModelAxis; 3] {
    [
        ModelAxis::Dense,
        ModelAxis::MoeStreaming { dispatch_ms: 0.0 },
        ModelAxis::Speculative {
            k: ModelAxis::SPEC_K,
            alpha: ModelAxis::SPEC_ALPHA,
        },
    ]
}

const RHO: f64 = 0.85;

/// Analytical tok/W for (model, context) on the calibrated H100 profile
/// — the same Eq. 2 operating point both engines plan with.
pub fn tok_per_watt(model: ModelAxis, context: u32) -> f64 {
    let p = model.profile_for(Gpu::H100);
    operating_point(&p, context, RHO, PowerAccounting::PerGpu)
        .tok_per_watt
        .0
}

/// Fraction of the zero-dispatch MoE edge over the dense baseline that
/// survives 10 ms of all-to-all dispatch at this context's
/// concurrency (n scaled ∝ 1/L from the 8K calibration anchor).
fn erosion_at_10ms(context: u32) -> f64 {
    let n = (128.0 * 8192.0 / context as f64).max(1.0);
    let rows = dispatch_erosion(
        &profiles::H100,
        &QWEN3_235B_A22B,
        &LLAMA31_70B,
        8,
        n,
        context as f64,
        &[0.0, 10.0],
    );
    rows[1].ratio / rows[0].ratio
}

/// The typed rowset behind the table.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 10 — model architecture as a scenario axis: context sweep \
         per model (H100, ρ=0.85, Eq. 2 operating points)",
        vec![
            Column::str("Model"),
            Column::int("context").with_unit("tok"),
            Column::int("n_max"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::float("x dense"),
            Column::float("halving"),
            Column::float("edge kept @10ms dispatch"),
        ],
    );
    for model in models() {
        let mut prev: Option<f64> = None;
        for ctx in CONTEXTS {
            let p = model.profile_for(Gpu::H100);
            let tpw = tok_per_watt(model, ctx);
            let vs_dense = tpw / tok_per_watt(ModelAxis::Dense, ctx);
            let halving = match prev {
                Some(prev_tpw) => Cell::float(tpw / prev_tpw)
                    .shown(format!("{:.3}", tpw / prev_tpw)),
                None => Cell::missing(),
            };
            let erosion = match model {
                ModelAxis::MoeStreaming { .. } => {
                    let e = erosion_at_10ms(ctx);
                    Cell::float(e).shown(format!("{:.0}%", e * 100.0))
                }
                _ => Cell::missing(),
            };
            rs.push(vec![
                Cell::str(model.label()),
                Cell::int(ctx as i64),
                Cell::int(p.n_max(ctx) as i64),
                Cell::float(tpw).shown(format!("{tpw:.2}")),
                Cell::float(vs_dense).shown(format!("{vs_dense:.2}x")),
                halving,
                erosion,
            ]);
            prev = Some(tpw);
        }
    }
    let dense_8k = tok_per_watt(ModelAxis::Dense, 8192);
    let moe_8k =
        tok_per_watt(ModelAxis::MoeStreaming { dispatch_ms: 0.0 }, 8192);
    let (_, dense_paper, _) = super::t2::PAPER[1];
    let (_, moe_paper, _) = super::t2::PAPER[3];
    rs.note(format!(
        "headline at 8K: dense {dense_8k:.2} tok/W vs qwen3-moe \
         {moe_8k:.2} tok/W = {:.2}x (paper Table 2: {dense_paper} vs \
         {moe_paper} = {:.1}x; the gap is the paper's own Table 2 \
         non-closure, documented in Table 2's notes)",
        moe_8k / dense_8k,
        moe_paper / dense_paper,
    ));
    rs.note(
        "the 1/W law survives the architecture lever: every halving \
         entry sits near 0.5 — weight streaming rescales W and H0 but \
         keeps tok/W ∝ 1/L, so routing gains multiply across models",
    );
    rs.note(
        "'edge kept' is the fraction of the zero-dispatch MoE advantage \
         over dense surviving 10 ms of all-to-all dispatch (§3.2's \
         upper-bound caveat); `wattlaw simulate --model qwen3-moe \
         --dispatch-ms 10` runs the eroded fleet end to end",
    );
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_the_moe_headline_within_the_acceptance_band() {
        // ISSUE 9 acceptance: qwen3-moe on H100 at 8K reports ≳35 tok/W
        // analytical and ≥4.5× the dense baseline.
        let dense = tok_per_watt(ModelAxis::Dense, 8192);
        let moe =
            tok_per_watt(ModelAxis::MoeStreaming { dispatch_ms: 0.0 }, 8192);
        assert!(moe >= 35.0, "moe @8K = {moe}");
        assert!(moe / dense >= 4.5, "edge = {}", moe / dense);
    }

    #[test]
    fn the_context_slope_survives_every_architecture() {
        for model in models() {
            for w in CONTEXTS.windows(2) {
                let ratio =
                    tok_per_watt(model, w[1]) / tok_per_watt(model, w[0]);
                assert!(
                    (0.45..=0.65).contains(&ratio),
                    "{}: tok/W({})/tok/W({}) = {ratio}",
                    model.label(),
                    w[1],
                    w[0]
                );
            }
        }
    }

    #[test]
    fn renders_all_models_with_erosion_on_the_moe_rows() {
        let rs = rowset();
        assert_eq!(rs.rows().len(), 3 * CONTEXTS.len());
        let s = rs.to_text();
        assert!(s.contains("Table 10"));
        for m in models() {
            assert!(s.contains(m.label()), "missing {}", m.label());
        }
        // Dispatch strictly erodes (but does not erase) the edge.
        for ctx in CONTEXTS {
            let e = erosion_at_10ms(ctx);
            assert!(e > 0.0 && e < 1.0, "erosion@{ctx} = {e}");
        }
    }
}
