//! Table 2 — single-GPU tok/W at n_max (8K context) across model families
//! (ComputedProfile: replicated KV; MoE rows stream active params only).

use super::render::{f0, tokw};
use crate::fleet::profile::{ComputedProfile, PowerAccounting};
use crate::model::spec::{ModelSpec, CATALOG, LLAMA31_8B};
use crate::results::{Cell, Column, RowSet};
use crate::model::KvPlacement;
use crate::power::profiles::{B200, H100};
use crate::power::GpuSpec;
use crate::tokeconomy::{operating_point, OperatingPoint};

pub const CTX: u32 = 8192;

#[derive(Debug, Clone)]
pub struct T2Row {
    pub model: &'static ModelSpec,
    pub tp: u32,
    pub h100: OperatingPoint,
    pub b200: OperatingPoint,
}

fn tp_for(model: &'static ModelSpec) -> u32 {
    if std::ptr::eq(model, &LLAMA31_8B) {
        1
    } else {
        8
    }
}

fn point(gpu: &'static GpuSpec, model: &'static ModelSpec, tp: u32) -> OperatingPoint {
    let p = ComputedProfile::new(gpu, model, tp, KvPlacement::Replicated);
    operating_point(&p, CTX, 1.0, PowerAccounting::PerGpu)
}

pub fn rows() -> Vec<T2Row> {
    CATALOG
        .iter()
        .map(|&m| {
            let tp = tp_for(m);
            T2Row {
                model: m,
                tp,
                h100: point(&H100, m, tp),
                b200: point(&B200, m, tp),
            }
        })
        .collect()
}

/// Paper's tok/W values for the comparison column:
/// (model name, h100 tok/W, b200 tok/W).
pub const PAPER: [(&str, f64, f64); 5] = [
    ("Llama-3.1-8B", 6.46, 12.18),
    ("Llama-3.1-70B", 7.41, 20.93),
    ("Llama-3.1-405B", 0.09, 2.16),
    ("Qwen3-235B-A22B", 37.82, 177.73),
    ("DeepSeek-V3", 2.14, 18.37),
];

/// The typed rowset behind the table.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 2 — single-GPU tok/W at n_max (8K context), ComputedProfile \
         (ours vs paper)",
        vec![
            Column::str("Model"),
            Column::int("TP"),
            Column::int("h100 n_max"),
            Column::float("h100 tok/s").with_unit("tok/s"),
            Column::float("h100 tok/W").with_unit("tok/J"),
            Column::float("h100 paper tok/W").with_unit("tok/J"),
            Column::int("b200 n_max"),
            Column::float("b200 tok/s").with_unit("tok/s"),
            Column::float("b200 tok/W").with_unit("tok/J"),
            Column::float("b200 paper tok/W").with_unit("tok/J"),
        ],
    );
    for (r, p) in rows().iter().zip(PAPER.iter()) {
        let moe = if r.model.is_moe { "†" } else { "" };
        rs.push(vec![
            Cell::str(format!("{}{moe}", r.model.name)),
            Cell::int(r.tp as i64),
            Cell::int(r.h100.n_max as i64),
            Cell::float(r.h100.throughput_tok_s)
                .shown(f0(r.h100.throughput_tok_s)),
            Cell::float(r.h100.tok_per_watt.0).shown(tokw(r.h100.tok_per_watt.0)),
            Cell::float(p.1).shown(tokw(p.1)),
            Cell::int(r.b200.n_max as i64),
            Cell::float(r.b200.throughput_tok_s)
                .shown(f0(r.b200.throughput_tok_s)),
            Cell::float(r.b200.tok_per_watt.0).shown(tokw(r.b200.tok_per_watt.0)),
            Cell::float(p.2).shown(tokw(p.2)),
        ]);
    }
    rs.note("† MoE: W streams active parameters only (upper bound — excludes dispatch)");
    rs.note("paper's MoE rows and P_sat parameterization do not close under its own \
            roofline; our values use the consistent model (EXPERIMENTS.md §T2)");
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_moe_beats_dense_of_similar_size() {
        let rs = rows();
        let dense70 = &rs[1];
        let qwen = &rs[3];
        assert!(qwen.model.is_moe);
        // Paper claims 5.1×; under the *self-consistent* roofline (the
        // paper's Table 2 MoE rows do not close — DESIGN.md §4) the edge
        // at replicated-KV n_max is ≈2×, still decisively MoE-favoring.
        assert!(
            qwen.h100.tok_per_watt.0 > 1.8 * dense70.h100.tok_per_watt.0,
            "MoE edge: {} vs {}",
            qwen.h100.tok_per_watt.0,
            dense70.h100.tok_per_watt.0
        );
    }

    #[test]
    fn shape_405b_unusable_on_h100_rescued_by_b200() {
        let rs = rows();
        let m405 = &rs[2];
        assert_eq!(m405.h100.n_max, 1);
        assert!(m405.h100.tok_per_watt.0 < 0.6, "{}", m405.h100.tok_per_watt.0);
        assert!(m405.b200.n_max >= 16);
        // "a 24× improvement" — escaping the near-idle regime is dramatic.
        assert!(
            m405.b200.tok_per_watt.0 / m405.h100.tok_per_watt.0 > 5.0,
            "B200 rescue: {} -> {}",
            m405.h100.tok_per_watt.0,
            m405.b200.tok_per_watt.0
        );
    }

    #[test]
    fn shape_b200_beats_h100_for_every_model() {
        for r in rows() {
            assert!(
                r.b200.tok_per_watt.0 > r.h100.tok_per_watt.0,
                "{}: {} vs {}",
                r.model.name,
                r.b200.tok_per_watt.0,
                r.h100.tok_per_watt.0
            );
        }
    }

    #[test]
    fn dense_n_max_matches_paper() {
        let rs = rows();
        assert!((57..=58).contains(&rs[0].h100.n_max)); // 8B
        assert!((22..=23).contains(&rs[1].h100.n_max)); // 70B
        assert_eq!(rs[2].h100.n_max, 1); // 405B
        assert!((16..=18).contains(&rs[2].b200.n_max)); // 405B on B200
    }

    #[test]
    fn renders_every_model() {
        let s = generate();
        for p in PAPER {
            assert!(s.contains(p.0), "missing {}", p.0);
        }
        assert!(s.contains("†"));
    }
}
