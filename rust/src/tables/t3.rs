//! Table 3 — fleet token efficiency at λ = 1000 req/s: three topologies ×
//! two GPU generations × two workload traces, sized to P99 TTFT ≤ 500 ms.

use std::sync::Arc;

use super::render::{f1, tokw, vs_pct};
use crate::fleet::analysis::{fleet_tpw_analysis, FleetReport};
use crate::results::{Cell, Column, RowSet};
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::workload::cdf::{azure_conversations, lmsys_chat, WorkloadTrace};

pub const LAMBDA: f64 = 1000.0;
pub const RHO: f64 = 0.85;
pub const SLO_S: f64 = 0.5;

#[derive(Debug, Clone)]
pub struct T3Row {
    pub trace: &'static str,
    pub topology: String,
    pub gpu: Gpu,
    pub report: FleetReport,
}

fn topologies(trace: &WorkloadTrace) -> Vec<Topology> {
    let b = trace.paper_b_short;
    vec![
        Topology::Homogeneous { ctx: LONG_CTX },
        Topology::PoolRouting { b_short: b, short_ctx: b.max(2048) },
        Topology::FleetOpt { b_short: b, short_ctx: b.max(2048), gamma: 2.0 },
    ]
}

pub fn rows(lbar: LBarPolicy) -> Vec<T3Row> {
    let mut out = Vec::new();
    for trace in [azure_conversations(), lmsys_chat()] {
        for gpu in [Gpu::H100, Gpu::B200] {
            let profile: Arc<dyn GpuProfile> =
                Arc::new(ManualProfile::for_gpu(gpu));
            for topo in topologies(&trace) {
                let pools = topo.pools(
                    &trace, LAMBDA, profile.clone(), None, lbar, RHO, SLO_S);
                let report = fleet_tpw_analysis(&pools, PowerAccounting::PerGpu);
                out.push(T3Row {
                    trace: trace.name,
                    topology: topo.label(),
                    gpu,
                    report,
                });
            }
        }
    }
    out
}

/// The typed rowset behind the table.
pub fn rowset(lbar: LBarPolicy) -> RowSet {
    let rs = rows(lbar);
    let mut out = RowSet::new(
        format!(
            "Table 3 — fleet token efficiency at λ=1000 req/s (L̄ policy: {lbar:?})"
        ),
        vec![
            Column::str("Workload"),
            Column::str("Topology"),
            Column::str("GPU"),
            Column::int("Groups"),
            Column::int("GPUs"),
            Column::float("power").with_unit("kW"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::float("vs H100 Homo").with_unit("%"),
        ],
    );
    // Baseline per trace: H100 homogeneous.
    let mut base = std::collections::HashMap::new();
    for r in &rs {
        if r.gpu == Gpu::H100 && r.topology.starts_with("Homo") {
            base.insert(r.trace, r.report.tok_per_watt.0);
        }
    }
    for r in &rs {
        let b = base[r.trace];
        let tpw = r.report.tok_per_watt.0;
        out.push(vec![
            Cell::str(r.trace),
            Cell::str(r.topology.clone()),
            Cell::str(r.gpu.spec().name),
            Cell::int(r.report.total_groups as i64),
            Cell::int(r.report.total_gpus as i64),
            Cell::float(r.report.total_power.kw())
                .shown(f1(r.report.total_power.kw())),
            Cell::float(tpw).shown(tokw(tpw)),
            Cell::float((tpw / b - 1.0) * 100.0).shown(vs_pct(tpw, b)),
        ]);
    }
    out.note("sized from first principles (decode throughput + Erlang-C TTFT tail); \
            the paper's absolute GPU counts do not close under its own Eq. 4 — \
            ratios are the reproduction target (EXPERIMENTS.md §T3)");
    out.note("power accounting: per-GPU (paper convention; see DESIGN.md §4.2)");
    out
}

pub fn generate(lbar: LBarPolicy) -> String {
    rowset(lbar).to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokw_of<'a>(rs: &'a [T3Row], trace: &str, topo_prefix: &str, gpu: Gpu) -> f64 {
        rs.iter()
            .find(|r| {
                r.trace == trace && r.topology.starts_with(topo_prefix) && r.gpu == gpu
            })
            .unwrap()
            .report
            .tok_per_watt
            .0
    }

    #[test]
    fn azure_orderings_match_paper() {
        let rs = rows(LBarPolicy::Window);
        for gpu in [Gpu::H100, Gpu::B200] {
            let homo = tokw_of(&rs, "Azure", "Homo", gpu);
            let pool = tokw_of(&rs, "Azure", "Pool", gpu);
            let opt = tokw_of(&rs, "Azure", "FleetOpt", gpu);
            assert!(homo < pool && pool < opt, "{gpu:?}: {homo} {pool} {opt}");
        }
    }

    #[test]
    fn generation_gain_is_about_1_7x_at_any_topology() {
        let rs = rows(LBarPolicy::Window);
        for topo in ["Homo", "Pool", "FleetOpt"] {
            let h = tokw_of(&rs, "Azure", topo, Gpu::H100);
            let b = tokw_of(&rs, "Azure", topo, Gpu::B200);
            let gain = b / h;
            assert!(
                (1.35..=2.1).contains(&gain),
                "{topo}: Δ_gen = {gain:.2} (paper ≈1.7)"
            );
        }
    }

    #[test]
    fn topology_gain_consistent_across_generations() {
        let rs = rows(LBarPolicy::Window);
        let d_h = tokw_of(&rs, "Azure", "FleetOpt", Gpu::H100)
            / tokw_of(&rs, "Azure", "Homo", Gpu::H100);
        let d_b = tokw_of(&rs, "Azure", "FleetOpt", Gpu::B200)
            / tokw_of(&rs, "Azure", "Homo", Gpu::B200);
        assert!(
            (d_h - d_b).abs() / d_h < 0.2,
            "Δ_topo(H100) = {d_h:.2} vs Δ_topo(B200) = {d_b:.2}"
        );
        assert!(d_h > 1.8, "topology must be a big lever: {d_h:.2}");
    }

    #[test]
    fn both_lbar_policies_preserve_the_ordering() {
        for lbar in [LBarPolicy::Window, LBarPolicy::TrafficMean] {
            let rs = rows(lbar);
            let homo = tokw_of(&rs, "LMSYS", "Homo", Gpu::H100);
            let opt = tokw_of(&rs, "LMSYS", "FleetOpt", Gpu::H100);
            assert!(opt > homo, "{lbar:?}: {opt} vs {homo}");
        }
    }

    #[test]
    fn renders_twelve_rows() {
        let s = generate(LBarPolicy::Window);
        assert_eq!(s.matches("Azure").count(), 6);
        assert_eq!(s.matches("LMSYS").count(), 6);
    }
}
