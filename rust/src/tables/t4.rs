//! Table 4 — context-window routing vs semantic routing per-pool
//! efficiency (H100, ρ = 0.85). The long pool is the binding constraint
//! in both schemes; semantic routing's case rests on per-physical-GPU
//! economics (8B runs TP=1), not per-group tok/W.

use super::render::{f0, tokw};
use crate::fleet::profile::{
    ComputedProfile, ManualProfile, PowerAccounting,
};
use crate::model::spec::LLAMA31_8B;
use crate::model::KvPlacement;
use crate::power::profiles::H100;
use crate::results::{Cell, Column, RowSet};
use crate::tokeconomy::{operating_point, OperatingPoint};

pub const RHO: f64 = 0.85;

#[derive(Debug, Clone)]
pub struct T4Row {
    pub pool: &'static str,
    pub model: &'static str,
    pub context: u32,
    pub op: OperatingPoint,
    /// Physical GPUs in the pool's serving unit (TP).
    pub tp: u32,
}

pub fn rows() -> Vec<T4Row> {
    let m70 = ManualProfile::h100_70b();
    let m8 = ComputedProfile::new(&H100, &LLAMA31_8B, 1, KvPlacement::Replicated);
    let acct = PowerAccounting::PerGpu;
    vec![
        T4Row {
            pool: "Context short (70B@8K)",
            model: "Llama-3.1-70B",
            context: 8192,
            op: operating_point(&m70, 8192, RHO, acct),
            tp: 8,
        },
        T4Row {
            pool: "Context long (70B@64K)",
            model: "Llama-3.1-70B",
            context: 65_536,
            op: operating_point(&m70, 65_536, RHO, acct),
            tp: 8,
        },
        T4Row {
            pool: "Semantic small (8B@8K)",
            model: "Llama-3.1-8B",
            context: 8192,
            op: operating_point(&m8, 8192, RHO, acct),
            tp: 1,
        },
        T4Row {
            pool: "Semantic large (70B@64K)",
            model: "Llama-3.1-70B",
            context: 65_536,
            op: operating_point(&m70, 65_536, RHO, acct),
            tp: 8,
        },
    ]
}

/// The typed rowset behind the table.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 4 — context-window routing vs semantic routing (H100, ρ=0.85)",
        vec![
            Column::str("Pool type"),
            Column::str("Model"),
            Column::str("Context"),
            Column::float("n_active"),
            Column::float("P").with_unit("W"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::float("tok/W per phys. GPU").with_unit("tok/J"),
        ],
    );
    for r in rows() {
        rs.push(vec![
            Cell::str(r.pool),
            Cell::str(r.model),
            Cell::str(super::render::ctx_k(r.context)),
            Cell::float(r.op.n_active).shown(f0(r.op.n_active)),
            Cell::float(r.op.power.0).shown(f0(r.op.power.0)),
            Cell::float(r.op.tok_per_watt.0).shown(tokw(r.op.tok_per_watt.0)),
            Cell::float(r.op.tok_per_watt.0 / r.tp as f64)
                .shown(tokw(r.op.tok_per_watt.0 / r.tp as f64)),
        ]);
    }
    rs.note("last column divides by TP — the paper's point that the 8B \
            semantic pool wins on a per-physical-GPU basis");
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_pools_tie_at_about_1_5_tok_w() {
        let rs = rows();
        let ctx_long = &rs[1];
        let sem_long = &rs[3];
        assert_eq!(ctx_long.op.tok_per_watt.0, sem_long.op.tok_per_watt.0);
        assert!(
            (ctx_long.op.tok_per_watt.0 - 1.52).abs() < 0.06,
            "long pool = {}",
            ctx_long.op.tok_per_watt.0
        );
    }

    #[test]
    fn short_pool_vs_paper() {
        let rs = rows();
        assert!(
            (rs[0].op.tok_per_watt.0 - 8.77).abs() < 0.2,
            "context-short = {}",
            rs[0].op.tok_per_watt.0
        );
    }

    #[test]
    fn long_pool_is_binding_constraint() {
        let rs = rows();
        // Short pool ≥ 5× the long pool's efficiency.
        assert!(rs[0].op.tok_per_watt.0 > 5.0 * rs[1].op.tok_per_watt.0);
    }

    #[test]
    fn semantic_small_wins_per_physical_gpu() {
        let rs = rows();
        let ctx_short_per_gpu = rs[0].op.tok_per_watt.0 / rs[0].tp as f64;
        let sem_small_per_gpu = rs[2].op.tok_per_watt.0 / rs[2].tp as f64;
        assert!(
            sem_small_per_gpu > ctx_short_per_gpu,
            "8B per-GPU {} vs 70B per-GPU {}",
            sem_small_per_gpu,
            ctx_short_per_gpu
        );
    }

    #[test]
    fn renders_four_pools() {
        let s = generate();
        assert!(s.contains("Context short"));
        assert!(s.contains("Semantic small"));
    }
}
