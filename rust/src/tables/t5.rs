//! Table 5 — GPU generation comparison for Llama-3.1-70B (TP=8, fp16) at
//! 8K context: hardware parameters, tok/W, and cost efficiency.

use super::render::{f0, f2, tokw};
use crate::fleet::profile::{ComputedProfile, GpuProfile, PowerAccounting};
use crate::model::spec::LLAMA31_70B;
use crate::model::KvPlacement;
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::tokeconomy::{mtok_per_dollar, operating_point, OperatingPoint};

pub const CTX: u32 = 8192;

#[derive(Debug, Clone)]
pub struct T5Row {
    pub gpu: Gpu,
    pub w_ms: f64,
    pub op: OperatingPoint,
    pub rental_per_hr: f64,
    pub mtok_per_dollar: f64,
}

pub fn rows() -> Vec<T5Row> {
    Gpu::ALL
        .iter()
        .map(|&gpu| {
            let p = ComputedProfile::new(
                gpu.spec(), &LLAMA31_70B, 8, KvPlacement::Replicated);
            let op = operating_point(&p, CTX, 1.0, PowerAccounting::PerGpu);
            let w_ms = p.roofline().w_ms;
            let rental = gpu.spec().rental_per_hr_tp8;
            T5Row {
                gpu,
                w_ms,
                mtok_per_dollar: mtok_per_dollar(&op, rental),
                op,
                rental_per_hr: rental,
            }
        })
        .collect()
}

/// The typed rowset behind the table.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 5 — GPU generation comparison, Llama-3.1-70B TP8 fp16 @8K",
        vec![
            Column::str("GPU"),
            Column::float("TDP").with_unit("W"),
            Column::float("P_idle").with_unit("W"),
            Column::float("W").with_unit("ms"),
            Column::int("n_max@8K"),
            Column::float("P_sat").with_unit("W"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::float("rental").with_unit("$/hr"),
            Column::float("Mtok/$").with_unit("Mtok/$"),
            Column::str("quality"),
        ],
    );
    for r in rows() {
        let s = r.gpu.spec();
        rs.push(vec![
            Cell::str(s.name),
            Cell::float(s.tdp_w).shown(f0(s.tdp_w)),
            Cell::float(s.power.p_idle_w).shown(f0(s.power.p_idle_w)),
            Cell::float(r.w_ms).shown(f2(r.w_ms)),
            Cell::int(r.op.n_max as i64),
            Cell::float(r.op.power.0).shown(f0(r.op.power.0)),
            Cell::float(r.op.tok_per_watt.0).shown(tokw(r.op.tok_per_watt.0)),
            Cell::float(r.rental_per_hr).shown(format!("{:.1}", r.rental_per_hr)),
            Cell::float(r.mtok_per_dollar).shown(f2(r.mtok_per_dollar)),
            Cell::str(s.quality.label()),
        ]);
    }
    rs.note("paper's P_sat column is inconsistent with its own logistic \
            parameters (e.g. 367 W at n=22 where P(22)=469 W); ours is the \
            self-consistent evaluation — see EXPERIMENTS.md §T5");
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_h200_substantially_beats_h100() {
        let rs = rows();
        let h100 = &rs[0];
        let h200 = &rs[1];
        let gain = h200.op.tok_per_watt.0 / h100.op.tok_per_watt.0;
        // Paper claims 2.1×; the replicated-KV scan term compresses the
        // self-consistent gain to ≈1.4–1.6× (EXPERIMENTS.md §T5).
        assert!((1.3..=2.6).contains(&gain), "H200/H100 = {gain:.2}");
        // n_max doubles: 44 vs 22.
        assert!((h200.op.n_max as f64 / h100.op.n_max as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn shape_b200_beats_h200_absolute() {
        let rs = rows();
        assert!(rs[2].op.tok_per_watt.0 > rs[1].op.tok_per_watt.0);
        assert!((56..=60).contains(&rs[2].op.n_max), "B200 n_max = {}", rs[2].op.n_max);
    }

    #[test]
    fn shape_gb200_below_b200_per_gpu() {
        // "GB200-NVL is a bit of a surprise": higher TDP outweighs the
        // slightly larger memory at this operating point.
        let rs = rows();
        assert!(
            rs[3].op.tok_per_watt.0 < rs[2].op.tok_per_watt.0,
            "GB200 {} must be below B200 {}",
            rs[3].op.tok_per_watt.0,
            rs[2].op.tok_per_watt.0
        );
        assert!(rs[3].op.n_max > rs[2].op.n_max, "but more sequences fit");
    }

    #[test]
    fn b200_wins_cost_efficiency_over_h200() {
        let rs = rows();
        assert!(
            rs[2].mtok_per_dollar > rs[1].mtok_per_dollar,
            "B200 {} vs H200 {} Mtok/$",
            rs[2].mtok_per_dollar,
            rs[1].mtok_per_dollar
        );
    }

    #[test]
    fn w_ms_matches_paper_per_gpu() {
        let rs = rows();
        assert!((rs[0].w_ms - 6.72).abs() < 0.05);
        assert!((rs[1].w_ms - 4.76).abs() < 0.1, "H200 W = {}", rs[1].w_ms);
        assert!((rs[2].w_ms - 2.95).abs() < 0.05);
    }

    #[test]
    fn quality_tags_present() {
        let s = generate();
        assert!(s.contains("HIGH"));
        assert!(s.contains("FAIR"));
    }
}
