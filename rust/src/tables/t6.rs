//! Table 6 — topology and GPU recommendations by workload archetype,
//! *computed* (not transcribed): for each archetype trace we sweep
//! topologies × GPU generations with the fleet analyzer and report the
//! argmax by tok/W, alongside the paper's recommendation.

use std::sync::Arc;

use super::render::tokw;
use crate::fleet::analysis::fleet_tpw_analysis;
use crate::results::{Cell, Column, RowSet};
use crate::fleet::pool::LBarPolicy;
use crate::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use crate::fleet::topology::{Topology, LONG_CTX};
use crate::power::Gpu;
use crate::workload::cdf::{
    agent_heavy, azure_conversations, lmsys_chat, Archetype, WorkloadTrace,
};

#[derive(Debug, Clone)]
pub struct T6Row {
    pub trace: &'static str,
    pub archetype: Archetype,
    pub frac_8k: f64,
    pub best_topology: String,
    pub best_gpu: Gpu,
    pub best_tok_w: f64,
    pub paper_topology: &'static str,
    pub paper_gpu: &'static str,
}

fn candidates(trace: &WorkloadTrace) -> Vec<Topology> {
    let b = trace.paper_b_short;
    vec![
        Topology::Homogeneous { ctx: LONG_CTX },
        Topology::PoolRouting { b_short: b, short_ctx: b.max(2048) },
        Topology::FleetOpt { b_short: b, short_ctx: b.max(2048), gamma: 2.0 },
    ]
}

pub fn rows() -> Vec<T6Row> {
    let specs: [(_, &'static str, &'static str); 3] = [
        (azure_conversations(), "FleetOpt two-pool", "B200"),
        (lmsys_chat(), "FleetOpt two-pool", "B200"),
        (agent_heavy(), "Pool routing / MoE lever", "H200 or B200"),
    ];
    specs
        .into_iter()
        .map(|(trace, paper_topology, paper_gpu)| {
            let mut best: Option<(String, Gpu, f64)> = None;
            for gpu in Gpu::ALL {
                let profile: Arc<dyn GpuProfile> =
                    Arc::new(ManualProfile::for_gpu(gpu));
                for topo in candidates(&trace) {
                    let pools = topo.pools(
                        &trace, 1000.0, profile.clone(), None,
                        LBarPolicy::Window, 0.85, 0.5);
                    let r = fleet_tpw_analysis(&pools, PowerAccounting::PerGpu);
                    let v = r.tok_per_watt.0;
                    if best.as_ref().map(|b| v > b.2).unwrap_or(true) {
                        best = Some((topo.label(), gpu, v));
                    }
                }
            }
            let (best_topology, best_gpu, best_tok_w) = best.unwrap();
            T6Row {
                trace: trace.name,
                archetype: trace.archetype(),
                frac_8k: trace.prompt_cdf.frac_leq(8192.0),
                best_topology,
                best_gpu,
                best_tok_w,
                paper_topology,
                paper_gpu,
            }
        })
        .collect()
}

/// The typed rowset behind the table.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 6 — topology and GPU recommendations by workload archetype \
         (computed argmax vs paper)",
        vec![
            Column::str("Trace"),
            Column::str("Archetype"),
            Column::float("≤8K").with_unit("%"),
            Column::str("Best topology (ours)"),
            Column::str("Best GPU (ours)"),
            Column::float("tok/W").with_unit("tok/J"),
            Column::str("Paper topology"),
            Column::str("Paper GPU"),
        ],
    );
    for r in rows() {
        rs.push(vec![
            Cell::str(r.trace),
            Cell::str(format!("{:?}", r.archetype)),
            Cell::float(r.frac_8k * 100.0)
                .shown(format!("{:.0}%", r.frac_8k * 100.0)),
            Cell::str(r.best_topology.clone()),
            Cell::str(r.best_gpu.spec().name),
            Cell::float(r.best_tok_w).shown(tokw(r.best_tok_w)),
            Cell::str(r.paper_topology),
            Cell::str(r.paper_gpu),
        ]);
    }
    rs.note("rankings by tok/W; B200/GB200 recommendations carry FAIR power-model \
            uncertainty (validate before procurement — paper Table 6 note)");
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_dominant_archetypes_pick_fleetopt() {
        for r in rows() {
            if r.archetype == Archetype::ShortDominant {
                assert!(
                    r.best_topology.contains("FleetOpt"),
                    "{}: picked {}",
                    r.trace,
                    r.best_topology
                );
            }
        }
    }

    #[test]
    fn best_gpu_is_a_blackwell_variant() {
        // Bigger KV budgets win the energy objective at every archetype.
        for r in rows() {
            assert!(
                matches!(r.best_gpu, Gpu::B200 | Gpu::GB200),
                "{}: picked {:?}",
                r.trace,
                r.best_gpu
            );
        }
    }

    #[test]
    fn archetype_classification() {
        let rs = rows();
        assert_eq!(rs[0].archetype, Archetype::ShortDominant); // Azure
        assert_eq!(rs[1].archetype, Archetype::ShortDominant); // LMSYS
        assert_eq!(rs[2].archetype, Archetype::Mixed); // agent-heavy, 74% ≤ 8K
    }

    #[test]
    fn renders_three_archetypes() {
        let s = generate();
        assert!(s.contains("Azure") && s.contains("LMSYS") && s.contains("Agent"));
    }
}
