//! Table 7 (Appendix A) — GPU power model parameters, plus the live
//! calibration loop: refit the logistic from regenerated ML.ENERGY-style
//! measurements and report the fit error (paper: <3 %).

use super::render::{f0, f2, Table};
use crate::power::fit::{fit_logistic, FitResult};
use crate::power::mlenergy;
use crate::power::Gpu;

pub fn calibration_fit() -> FitResult {
    fit_logistic(&mlenergy::h100_measurements(0, 0.03))
}

pub fn generate() -> String {
    let mut t = Table::new(
        "Table 7 — GPU power model parameters",
        &["GPU", "TDP (W)", "P_idle (W)", "P_nom (W)", "k", "x0", "Quality"],
    );
    for gpu in Gpu::ALL {
        let s = gpu.spec();
        t.row(vec![
            s.name.to_string(),
            f0(s.tdp_w),
            f0(s.power.p_idle_w),
            f0(s.power.p_nom_w),
            f2(s.power.k),
            f2(s.power.x0),
            s.quality.label().to_string(),
        ]);
    }
    t.note("B200/GB200 x0 = 4.45 (closes the paper's own Table 1 power \
            column; the published 6.8 does not — EXPERIMENTS.md §T7)");

    // Live calibration loop on regenerated measurements.
    let fit = calibration_fit();
    let mut c = Table::new(
        "Calibration — logistic refit from ML.ENERGY-style H100 samples",
        &["parameter", "published", "refit"],
    );
    c.row(vec!["P_idle (W)".into(), "300".into(), f0(fit.model.p_idle_w)]);
    c.row(vec!["P_nom (W)".into(), "600".into(), f0(fit.model.p_nom_w)]);
    c.row(vec!["k".into(), "1.0".into(), f2(fit.model.k)]);
    c.row(vec!["x0".into(), "4.2".into(), f2(fit.model.x0)]);
    c.row(vec![
        "max rel fit error".into(),
        "<3%".into(),
        format!("{:.1}%", fit.max_rel_err * 100.0),
    ]);
    format!("{}{}", t.render(), c.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_error_within_paper_band() {
        let fit = calibration_fit();
        assert!(
            fit.max_rel_err < 0.06,
            "fit error {:.3} vs paper's <3% + 3% regen noise",
            fit.max_rel_err
        );
        assert!((fit.model.p_idle_w - 300.0).abs() < 20.0);
        assert!((fit.model.x0 - 4.2).abs() < 0.4);
    }

    #[test]
    fn renders_all_gpus_and_calibration() {
        let s = generate();
        for g in Gpu::ALL {
            assert!(s.contains(g.spec().name));
        }
        assert!(s.contains("refit"));
    }
}
