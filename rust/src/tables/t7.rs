//! Table 7 (Appendix A) — GPU power model parameters, plus the live
//! calibration loop: refit the logistic from regenerated ML.ENERGY-style
//! measurements and report the fit error (paper: <3 %).

use super::render::{f0, f2};
use crate::power::fit::{fit_logistic, FitResult};
use crate::power::mlenergy;
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};

pub fn calibration_fit() -> FitResult {
    fit_logistic(&mlenergy::h100_measurements(0, 0.03))
}

/// The typed rowsets behind the two tables: parameter catalog +
/// calibration refit.
pub fn rowsets() -> Vec<RowSet> {
    let mut rs = RowSet::new(
        "Table 7 — GPU power model parameters",
        vec![
            Column::str("GPU"),
            Column::float("TDP").with_unit("W"),
            Column::float("P_idle").with_unit("W"),
            Column::float("P_nom").with_unit("W"),
            Column::float("k"),
            Column::float("x0"),
            Column::str("Quality"),
        ],
    );
    for gpu in Gpu::ALL {
        let s = gpu.spec();
        rs.push(vec![
            Cell::str(s.name),
            Cell::float(s.tdp_w).shown(f0(s.tdp_w)),
            Cell::float(s.power.p_idle_w).shown(f0(s.power.p_idle_w)),
            Cell::float(s.power.p_nom_w).shown(f0(s.power.p_nom_w)),
            Cell::float(s.power.k).shown(f2(s.power.k)),
            Cell::float(s.power.x0).shown(f2(s.power.x0)),
            Cell::str(s.quality.label()),
        ]);
    }
    rs.note("B200/GB200 x0 = 4.45 (closes the paper's own Table 1 power \
            column; the published 6.8 does not — EXPERIMENTS.md §T7)");

    // Live calibration loop on regenerated measurements.
    let fit = calibration_fit();
    let mut c = RowSet::new(
        "Calibration — logistic refit from ML.ENERGY-style H100 samples",
        vec![
            Column::str("parameter"),
            Column::str("published"),
            Column::float("refit"),
        ],
    );
    c.push(vec![
        Cell::str("P_idle (W)"),
        Cell::str("300"),
        Cell::float(fit.model.p_idle_w).shown(f0(fit.model.p_idle_w)),
    ]);
    c.push(vec![
        Cell::str("P_nom (W)"),
        Cell::str("600"),
        Cell::float(fit.model.p_nom_w).shown(f0(fit.model.p_nom_w)),
    ]);
    c.push(vec![
        Cell::str("k"),
        Cell::str("1.0"),
        Cell::float(fit.model.k).shown(f2(fit.model.k)),
    ]);
    c.push(vec![
        Cell::str("x0"),
        Cell::str("4.2"),
        Cell::float(fit.model.x0).shown(f2(fit.model.x0)),
    ]);
    c.push(vec![
        Cell::str("max rel fit error"),
        Cell::str("<3%"),
        Cell::float(fit.max_rel_err * 100.0)
            .shown(format!("{:.1}%", fit.max_rel_err * 100.0)),
    ]);
    vec![rs, c]
}

pub fn generate() -> String {
    rowsets().iter().map(|r| r.to_text()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_error_within_paper_band() {
        let fit = calibration_fit();
        assert!(
            fit.max_rel_err < 0.06,
            "fit error {:.3} vs paper's <3% + 3% regen noise",
            fit.max_rel_err
        );
        assert!((fit.model.p_idle_w - 300.0).abs() < 20.0);
        assert!((fit.model.x0 - 4.2).abs() < 0.4);
    }

    #[test]
    fn renders_all_gpus_and_calibration() {
        let s = generate();
        for g in Gpu::ALL {
            assert!(s.contains(g.spec().name));
        }
        assert!(s.contains("refit"));
    }
}
