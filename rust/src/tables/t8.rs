//! Table 8 — K-pool context partitions: the 1/W law harvested at finer
//! granularity.
//!
//! The headline FleetOpt gain comes from a *two*-pool split, but the
//! law (tok/W halves per context doubling) keeps paying as long as each
//! pool's window tracks its traffic slice: this table walks K ∈ 1..=4
//! on the default powers-of-four ladder
//! ([`default_partition`]) over the dispersed agent-heavy workload and
//! pairs the closed-form Eq. 4 tok/W with the event-driven simulator's
//! measured tok/W and p99 TTFT per K — the same analyze-vs-simulate
//! cross-check every sweep cell carries.

use crate::fleet::profile::PowerAccounting;
use crate::fleet::topology::{default_partition, Topology, LONG_CTX};
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::scenario::{rel_delta_pct, ScenarioSpec};
use crate::workload::cdf::agent_heavy;
use crate::workload::synth::GenConfig;

/// One shared traffic model for every K cell (deterministic seed).
fn t8_gen() -> GenConfig {
    GenConfig {
        lambda_rps: 120.0,
        duration_s: 2.0,
        max_prompt_tokens: 60_000,
        max_output_tokens: 256,
        seed: 42,
    }
}

/// The scenario cell behind one K row: K=1 is the homogeneous 64K
/// baseline, K ≥ 2 the default-ladder partition.
pub fn spec_for_k(k: u32) -> ScenarioSpec {
    let topo = if k == 1 {
        Topology::Homogeneous { ctx: LONG_CTX }
    } else {
        Topology::partition(&default_partition(k))
    };
    ScenarioSpec::new(topo, Gpu::H100, agent_heavy(), t8_gen()).with_groups(8)
}

/// The typed rowset behind the table: K vs tok/W (both engines) vs
/// p99 TTFT.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 8 — K-pool context partitions \
         (agent-heavy, H100, λ=120 req/s, 8 groups)",
        vec![
            Column::int("K"),
            Column::str("topology"),
            Column::float("analyze tok/W").with_unit("tok/J"),
            Column::float("simulate tok/W").with_unit("tok/J"),
            Column::float("delta").with_unit("%"),
            Column::float("p99 TTFT").with_unit("s"),
            Column::int("completed"),
        ],
    );
    for k in 1..=4u32 {
        let spec = spec_for_k(k);
        let analytic = spec.analyze(PowerAccounting::PerGpu);
        let sim = spec.simulate(true);
        let delta = rel_delta_pct(sim.tok_per_watt, analytic.tok_per_watt.0);
        rs.push(vec![
            Cell::int(k as i64),
            Cell::str(sim.topology.clone()),
            Cell::float(analytic.tok_per_watt.0)
                .shown(format!("{:.3}", analytic.tok_per_watt.0)),
            Cell::float(sim.tok_per_watt)
                .shown(format!("{:.3}", sim.tok_per_watt)),
            Cell::float(delta).shown(format!("{delta:+.1}%")),
            Cell::float(sim.p99_ttft_s)
                .shown(format!("{:.3}", sim.p99_ttft_s)),
            Cell::int(sim.completed as i64),
        ]);
    }
    rs.note(
        "same traffic, same total groups; only the context partition \
         changes — finer partitions keep harvesting the 1/W law as long \
         as each pool's window tracks its traffic slice",
    );
    rs.note(
        "cutoffs are the default powers-of-four ladder (K=3 is the \
         paper's §10.3 {4K|16K|64K}); `wattlaw optimize --pools K` \
         searches the full cutoff grids instead",
    );
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_every_k_with_both_engines() {
        let rs = rowset();
        assert_eq!(rs.rows().len(), 4);
        let s = rs.to_text();
        assert!(s.contains("Table 8"));
        assert!(s.contains("Homo 64K"));
        assert!(s.contains("3-pool"));
        assert!(s.contains("4-pool"));
        // Every K cell conserves the shared trace's tokens.
        let want: u64 = spec_for_k(1)
            .trace()
            .iter()
            .map(|r| r.output_tokens as u64)
            .sum();
        for k in [1u32, 3] {
            let sim = spec_for_k(k).simulate(true);
            assert_eq!(sim.output_tokens, want, "K={k}");
        }
    }

    #[test]
    fn partitioning_beats_the_homogeneous_baseline_analytically() {
        let homo = spec_for_k(1).analyze(PowerAccounting::PerGpu);
        let k3 = spec_for_k(3).analyze(PowerAccounting::PerGpu);
        assert!(
            k3.tok_per_watt.0 > homo.tok_per_watt.0,
            "K=3 {} vs homo {}",
            k3.tok_per_watt.0,
            homo.tok_per_watt.0
        );
    }
}
