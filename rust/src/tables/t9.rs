//! Table 9 — heterogeneous fleets: where does a newer GPU generation
//! buy the most?
//!
//! The paper's independence result (§4.2) says the routing lever and
//! the generation lever multiply when the *whole* fleet upgrades. A
//! heterogeneity-native stack can ask the finer question operators
//! actually face: with a K-pool context partition and a limited number
//! of B200 groups, which pool should get them? This table walks
//! K ∈ {2, 3} on the default powers-of-four ladder over the agent-heavy
//! workload and reports, per K, the homogeneous-H100 floor, the best
//! mixed H100/B200 assignment (chosen by the closed-form Eq. 4 screen
//! over the full {H100, B200}^K cross-product), and the homogeneous-B200
//! ceiling — analytical and simulated tok/W side by side with p99 TTFT,
//! plus the marginal tok/W per upgraded group that turns the
//! independence claim into a placement curve.

use crate::fleet::profile::PowerAccounting;
use crate::fleet::topology::{default_partition, Topology};
use crate::power::Gpu;
use crate::results::{Cell, Column, RowSet};
use crate::scenario::optimize::assignment_label;
use crate::scenario::{rel_delta_pct, ScenarioSpec};
use crate::workload::cdf::agent_heavy;
use crate::workload::synth::GenConfig;

/// One shared traffic model for every cell (deterministic seed; the
/// long-prompt-heavy archetype, where generation placement matters
/// most).
fn t9_gen() -> GenConfig {
    GenConfig {
        lambda_rps: 120.0,
        duration_s: 1.5,
        max_prompt_tokens: 60_000,
        max_output_tokens: 256,
        seed: 42,
    }
}

/// The scenario cell behind one row: the default K-pool ladder with an
/// explicit per-pool GPU assignment.
pub fn spec_for(k: u32, gpus: &[Gpu]) -> ScenarioSpec {
    let cuts = default_partition(k);
    assert_eq!(cuts.len(), gpus.len());
    ScenarioSpec::new(
        Topology::partition_with_gpus(&cuts, gpus, 1.0),
        gpus[0],
        agent_heavy(),
        t9_gen(),
    )
    .with_groups(8)
}

/// Every {H100, B200}^K assignment vector, homogeneous endpoints
/// included, in deterministic binary-counter order.
fn assignments(k: u32) -> Vec<Vec<Gpu>> {
    (0..1u32 << k)
        .map(|code| {
            (0..k)
                .map(|i| {
                    if (code >> (k - 1 - i)) & 1 == 1 {
                        Gpu::B200
                    } else {
                        Gpu::H100
                    }
                })
                .collect()
        })
        .collect()
}

/// The analytically best *mixed* assignment for the K-pool ladder —
/// the cross-product screened with the same Eq. 4 path as the
/// optimizer's stage A.
pub fn best_mixed(k: u32) -> Vec<Gpu> {
    assignments(k)
        .into_iter()
        .filter(|v| v.windows(2).any(|w| w[0] != w[1]))
        .map(|v| {
            // Evaluate each candidate once, not per comparison.
            let tok_w = spec_for(k, &v)
                .analyze(PowerAccounting::PerGpu)
                .tok_per_watt
                .0;
            (tok_w, v)
        })
        .max_by(|(a, _), (b, _)| a.total_cmp(b))
        .map(|(_, v)| v)
        .expect("K >= 2 has mixed assignments")
}

/// The typed rowset behind the table: per K, the H100 floor, the best
/// mixed placement, and the B200 ceiling.
pub fn rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Table 9 — heterogeneous fleets: GPU-generation placement across \
         K-pool partitions (agent-heavy, λ=120 req/s, 8 groups)",
        vec![
            Column::int("K"),
            Column::str("fleet"),
            Column::float("analyze tok/W").with_unit("tok/J"),
            Column::float("simulate tok/W").with_unit("tok/J"),
            Column::float("delta").with_unit("%"),
            Column::float("p99 TTFT").with_unit("s"),
            Column::int("upgraded groups"),
            Column::float("marginal tok/W").with_unit("tok/J per group"),
        ],
    );
    for k in [2u32, 3] {
        let floor = vec![Gpu::H100; k as usize];
        let ceiling = vec![Gpu::B200; k as usize];
        let mixed = best_mixed(k);
        let floor_tok_w = spec_for(k, &floor)
            .analyze(PowerAccounting::PerGpu)
            .tok_per_watt
            .0;
        for gpus in [floor, mixed, ceiling] {
            let spec = spec_for(k, &gpus);
            let analytic = spec.analyze(PowerAccounting::PerGpu);
            let sim = spec.simulate(true);
            let delta =
                rel_delta_pct(sim.tok_per_watt, analytic.tok_per_watt.0);
            // Upgraded groups by the analytical plan's own sizing — the
            // denominator of the placement curve.
            let upgraded: u64 = analytic
                .pools
                .iter()
                .zip(&gpus)
                .filter(|(_, g)| **g == Gpu::B200)
                .map(|(p, _)| p.sizing.groups)
                .sum();
            let marginal_cell = if upgraded > 0 {
                let m = (analytic.tok_per_watt.0 - floor_tok_w)
                    / upgraded as f64;
                Cell::float(m).shown(format!("{m:.4}"))
            } else {
                Cell::missing()
            };
            rs.push(vec![
                Cell::int(k as i64),
                Cell::str(assignment_label(&gpus)),
                Cell::float(analytic.tok_per_watt.0)
                    .shown(format!("{:.3}", analytic.tok_per_watt.0)),
                Cell::float(sim.tok_per_watt)
                    .shown(format!("{:.3}", sim.tok_per_watt)),
                Cell::float(delta).shown(format!("{delta:+.1}%")),
                Cell::float(sim.p99_ttft_s)
                    .shown(format!("{:.3}", sim.p99_ttft_s)),
                Cell::int(upgraded as i64),
                marginal_cell,
            ]);
        }
    }
    rs.note(
        "same traffic, same total simulated groups; only the per-pool \
         GPU assignment changes — the mixed row is the closed-form \
         winner of the {H100,B200}^K cross-product, and 'marginal \
         tok/W' is its analytical gain over the all-H100 floor per \
         upgraded group (the generation lever as a placement curve)",
    );
    rs.note(
        "cutoffs are the default powers-of-four ladder; `wattlaw \
         optimize --pools K --hetero` searches assignments across the \
         full cutoff grids, `--upgrade-budget N` places a limited B200 \
         budget greedily",
    );
    rs
}

pub fn generate() -> String {
    rowset().to_text()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_floor_mixed_and_ceiling_for_each_k() {
        let rs = rowset();
        assert_eq!(rs.rows().len(), 6, "3 fleets × K in {{2, 3}}");
        let s = rs.to_text();
        assert!(s.contains("Table 9"));
        assert!(s.contains("H100-SXM5"), "homogeneous floor row");
        assert!(s.contains("B200-SXM"), "homogeneous ceiling row");
        assert!(s.contains('|'), "a mixed assignment row");
    }

    #[test]
    fn generation_ordering_holds_analytically() {
        // Floor < best mixed ≤ ceiling, for both K — the placement
        // curve is monotone in upgraded pools.
        for k in [2u32, 3] {
            let tw = |gpus: &[Gpu]| {
                spec_for(k, gpus)
                    .analyze(PowerAccounting::PerGpu)
                    .tok_per_watt
                    .0
            };
            let floor = tw(&vec![Gpu::H100; k as usize]);
            let mixed = tw(&best_mixed(k));
            let ceiling = tw(&vec![Gpu::B200; k as usize]);
            assert!(mixed > floor, "K={k}: mixed {mixed} vs floor {floor}");
            assert!(
                ceiling >= mixed,
                "K={k}: ceiling {ceiling} vs mixed {mixed}"
            );
        }
    }
}
