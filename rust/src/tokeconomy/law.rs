//! The 1/W law itself (paper §3.1): tok/W halves every time the serving
//! context window doubles. This module turns the claim into measurable
//! statistics:
//!
//! * the log–log slope of tok/W vs context (the law predicts −1),
//! * per-doubling halving ratios,
//! * the end-to-end spread across the 2K–128K range (paper: "nearly 40×").

use crate::fleet::profile::{GpuProfile, PowerAccounting};
use crate::tokeconomy::{context_sweep, OperatingPoint};

/// The standard 2K–128K sweep grid.
pub const LAW_CONTEXTS: [u32; 7] =
    [2048, 4096, 8192, 16384, 32768, 65536, 131072];

/// Fitted law statistics for one profile.
#[derive(Debug, Clone)]
pub struct LawFit {
    pub points: Vec<OperatingPoint>,
    /// Least-squares slope of log2(tok/W) against log2(context).
    pub slope: f64,
    /// tok/W ratio between successive context doublings (ideal: 2.0 each).
    pub halving_ratios: Vec<f64>,
    /// max(tok/W) / min(tok/W) across the sweep.
    pub spread: f64,
}

/// Fit the law on a profile over `contexts` at full occupancy.
pub fn fit_law(profile: &dyn GpuProfile, contexts: &[u32]) -> LawFit {
    let points = context_sweep(profile, contexts, PowerAccounting::PerGpu);
    let xs: Vec<f64> = points.iter().map(|p| (p.context as f64).log2()).collect();
    let ys: Vec<f64> = points.iter().map(|p| p.tok_per_watt.0.log2()).collect();
    let slope = least_squares_slope(&xs, &ys);

    let halving_ratios = points
        .windows(2)
        .map(|w| w[0].tok_per_watt.0 / w[1].tok_per_watt.0)
        .collect();

    let (mut lo, mut hi) = (f64::INFINITY, 0.0f64);
    for p in &points {
        lo = lo.min(p.tok_per_watt.0);
        hi = hi.max(p.tok_per_watt.0);
    }

    LawFit {
        points,
        slope,
        halving_ratios,
        spread: hi / lo,
    }
}

/// Ordinary least squares slope.
pub fn least_squares_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;

    /// The law's slope on the paper's own Table 1 data is −0.886 (35.0 →
    /// 0.88 over six doublings), not the idealized −1: at long context the
    /// *power* term also falls (P(8) = 369 W vs P(512) = 598 W), which
    /// softens the halving. Our model reproduces exactly that slope.
    #[test]
    fn slope_matches_paper_table1_data_on_h100() {
        let fit = fit_law(&ManualProfile::h100_70b(), &LAW_CONTEXTS);
        let paper_slope = ((0.88f64 / 35.0).log2()) / 6.0; // −0.8865
        assert!(
            (fit.slope - paper_slope).abs() < 0.03,
            "log-log slope = {} (paper's own data: {paper_slope:.3})",
            fit.slope
        );
        assert!(fit.slope < -0.8 && fit.slope > -1.05);
    }

    #[test]
    fn slope_is_the_same_on_b200() {
        // "B200 shifts the curve up but does not change the slope."
        let h = fit_law(&ManualProfile::h100_70b(), &LAW_CONTEXTS);
        let b = fit_law(&ManualProfile::b200_70b(), &LAW_CONTEXTS);
        assert!((h.slope - b.slope).abs() < 0.06,
                "H100 {} vs B200 {}", h.slope, b.slope);
        assert!(b.slope < -0.8 && b.slope > -1.05, "slope = {}", b.slope);
    }

    #[test]
    fn every_doubling_roughly_halves_tok_per_watt() {
        // Paper Table 1's own per-doubling ratios run 1.70–1.99 (power
        // decay at small n_max softens the tail doublings).
        let fit = fit_law(&ManualProfile::h100_70b(), &LAW_CONTEXTS);
        for (i, r) in fit.halving_ratios.iter().enumerate() {
            assert!(
                (1.65..=2.1).contains(r),
                "doubling {i}: ratio = {r} (law predicts ≈2)"
            );
        }
        // The short-context end, where power is flat, halves tightly.
        assert!((fit.halving_ratios[0] - 2.0).abs() < 0.05);
    }

    #[test]
    fn spread_is_about_forty_x() {
        // Paper: "extends to a nearly 40× spread across 2K to 128K".
        let fit = fit_law(&ManualProfile::h100_70b(), &LAW_CONTEXTS);
        assert!(
            (35.0..=45.0).contains(&fit.spread),
            "2K..128K spread = {:.1}x",
            fit.spread
        );
    }

    #[test]
    fn law_holds_even_at_moderate_subsets() {
        // In the saturated-power regime (2K–16K) the slope is ≈ −1 proper.
        let fit = fit_law(&ManualProfile::h100_70b(), &[2048, 4096, 8192, 16384]);
        assert!((fit.slope + 1.0).abs() < 0.06, "slope = {}", fit.slope);
    }
}
