//! Single-GPU token economy — paper Eq. (2):
//!
//! ```text
//! tok/W = (n_active / τ(n_active, L̄)) / P(n_active)
//! ```
//!
//! An [`OperatingPoint`] bundles everything Table 1/2/4/5 report about one
//! (profile, context, utilization) triple.

pub mod law;

use crate::fleet::profile::{GpuProfile, PowerAccounting};
use crate::units::{TokensPerWatt, Watts};

/// One fully-evaluated serving operating point.
#[derive(Debug, Clone)]
pub struct OperatingPoint {
    /// Serving context window, tokens.
    pub context: u32,
    /// Eq. (3) concurrency limit at this window.
    pub n_max: u32,
    /// Mean in-flight batch (ρ · n_max).
    pub n_active: f64,
    /// Mean KV length assumed for the scan term.
    pub l_bar: f64,
    /// Per-iteration decode latency, ms.
    pub tau_ms: f64,
    /// Decode throughput, output tokens/s (per TP group).
    pub throughput_tok_s: f64,
    /// Power denominator, watts (per GPU or per group — see accounting).
    pub power: Watts,
    /// The headline figure of merit.
    pub tok_per_watt: TokensPerWatt,
}

/// Evaluate Eq. (2) at utilization `rho` of the window's `n_max`, with the
/// paper's convention `L̄ = context window` (full-occupancy conservative
/// bound; Tables 1 and 4 verifiably use this).
pub fn operating_point(
    profile: &dyn GpuProfile,
    context: u32,
    rho: f64,
    acct: PowerAccounting,
) -> OperatingPoint {
    operating_point_with_lbar(profile, context, rho, context as f64, acct)
}

/// Evaluate Eq. (2) with an explicit mean KV length (used by the fleet
/// model's `TrafficMean` ablation, where L̄ comes from the workload CDF).
pub fn operating_point_with_lbar(
    profile: &dyn GpuProfile,
    context: u32,
    rho: f64,
    l_bar: f64,
    acct: PowerAccounting,
) -> OperatingPoint {
    assert!((0.0..=1.0).contains(&rho), "utilization must be in [0,1]");
    let n_max = profile.n_max(context);
    let n_active = (rho * n_max as f64).max(0.0);
    let r = profile.roofline();
    let tau_ms = r.tau_ms(n_active, l_bar);
    let throughput = r.throughput_tok_s(n_active, l_bar);
    let power_w = profile.group_power_w(n_active, acct);
    OperatingPoint {
        context,
        n_max,
        n_active,
        l_bar,
        tau_ms,
        throughput_tok_s: throughput,
        power: Watts(power_w),
        tok_per_watt: TokensPerWatt(if power_w > 0.0 {
            throughput / power_w
        } else {
            0.0
        }),
    }
}

/// Table-1-style context sweep at full occupancy (ρ = 1).
pub fn context_sweep(
    profile: &dyn GpuProfile,
    contexts: &[u32],
    acct: PowerAccounting,
) -> Vec<OperatingPoint> {
    contexts
        .iter()
        .map(|&c| operating_point(profile, c, 1.0, acct))
        .collect()
}

/// Cost efficiency (Table 5): output tokens per dollar, in millions of
/// tokens per $M… the paper reports "tok/$M/hr" = Mtok per group-hour per
/// rental dollar; we report Mtok/$ directly.
pub fn mtok_per_dollar(op: &OperatingPoint, rental_per_hr_group: f64) -> f64 {
    let tok_per_hr = op.throughput_tok_s * 3600.0;
    tok_per_hr / rental_per_hr_group / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::profile::ManualProfile;

    const T1_CONTEXTS: [u32; 7] =
        [2048, 4096, 8192, 16384, 32768, 65536, 131072];

    /// Table 1 H100 column, every row, to ≤1.5 % — the calibration anchor
    /// for the whole crate.
    #[test]
    fn table1_h100_tok_per_watt_closes() {
        let p = ManualProfile::h100_70b();
        let want = [35.0, 17.6, 8.97, 4.69, 2.58, 1.50, 0.88];
        for (i, ops) in
            context_sweep(&p, &T1_CONTEXTS, PowerAccounting::PerGpu)
                .iter()
                .enumerate()
        {
            let got = ops.tok_per_watt.0;
            let w = want[i];
            assert!(
                ((got - w) / w).abs() < 0.015,
                "ctx {}: tok/W = {got:.3}, paper {w}",
                T1_CONTEXTS[i]
            );
        }
    }

    /// Table 1 B200 column to ≤3 % (FAIR projection; floor rounding of
    /// n_max differs from the paper's unfloored scaling in places).
    #[test]
    fn table1_b200_tok_per_watt_closes() {
        let p = ManualProfile::b200_70b();
        let want = [61.4, 30.8, 15.5, 7.87, 4.09, 2.24, 1.30];
        for (i, ops) in
            context_sweep(&p, &T1_CONTEXTS, PowerAccounting::PerGpu)
                .iter()
                .enumerate()
        {
            let got = ops.tok_per_watt.0;
            let w = want[i];
            assert!(
                ((got - w) / w).abs() < 0.03,
                "ctx {}: tok/W = {got:.3}, paper {w}",
                T1_CONTEXTS[i]
            );
        }
    }

    /// §3.1: "B200 is only 1.49× better than H100 at 64K, down from 1.75×
    /// at 4K" — idle power eats the advantage at low concurrency.
    #[test]
    fn b200_advantage_narrows_at_long_context() {
        let h = ManualProfile::h100_70b();
        let b = ManualProfile::b200_70b();
        let at = |ctx| {
            operating_point(&b, ctx, 1.0, PowerAccounting::PerGpu)
                .tok_per_watt
                .0
                / operating_point(&h, ctx, 1.0, PowerAccounting::PerGpu)
                    .tok_per_watt
                    .0
        };
        let r4k = at(4096);
        let r64k = at(65536);
        assert!((r4k - 1.75).abs() < 0.08, "4K ratio = {r4k}");
        assert!((r64k - 1.49).abs() < 0.05, "64K ratio = {r64k}");
        assert!(r64k < r4k);
    }

    /// Table 4's context-short pool row: ρ=0.85 at 8K.
    #[test]
    fn table4_context_short_pool() {
        let p = ManualProfile::h100_70b();
        let op = operating_point(&p, 8192, 0.85, PowerAccounting::PerGpu);
        assert!((op.n_active - 108.8).abs() < 0.01);
        assert!((op.power.0 - 578.0).abs() < 2.0, "P = {}", op.power.0);
        assert!(
            (op.tok_per_watt.0 - 8.77).abs() < 0.15,
            "tok/W = {}",
            op.tok_per_watt.0
        );
    }

    /// Table 4's long pool rows: ρ=0.85 at 64K → 1.52 tok/W.
    #[test]
    fn table4_long_pool() {
        let p = ManualProfile::h100_70b();
        let op = operating_point(&p, 65536, 0.85, PowerAccounting::PerGpu);
        assert!((op.n_active - 13.6).abs() < 0.01);
        // Paper rounds n_active down to 13 (413 W); at 13.6 the logistic
        // gives 418 W. Allow the rounding gap.
        assert!((op.power.0 - 413.0).abs() < 6.0, "P = {}", op.power.0);
        assert!(
            (op.tok_per_watt.0 - 1.52).abs() < 0.05,
            "tok/W = {}",
            op.tok_per_watt.0
        );
    }

    #[test]
    fn per_group_accounting_divides_by_tp() {
        let p = ManualProfile::h100_70b();
        let gpu = operating_point(&p, 8192, 1.0, PowerAccounting::PerGpu);
        let grp = operating_point(&p, 8192, 1.0, PowerAccounting::PerGroup);
        assert!((gpu.tok_per_watt.0 / grp.tok_per_watt.0 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn zero_utilization_burns_idle_power_for_nothing() {
        let p = ManualProfile::h100_70b();
        let op = operating_point(&p, 8192, 0.0, PowerAccounting::PerGpu);
        assert_eq!(op.throughput_tok_s, 0.0);
        assert_eq!(op.power.0, 300.0);
        assert_eq!(op.tok_per_watt.0, 0.0);
    }
}
