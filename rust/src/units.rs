//! Thin typed units used across the analytical models.
//!
//! These are deliberately lightweight wrappers over `f64`/`u64`: the goal is
//! self-documenting signatures (`Watts`, `Joules`, `TokensPerWatt`) and a
//! couple of dimension-correct conversions, not a full dimensional-analysis
//! system.

use std::fmt;

/// Electrical power in watts.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Watts(pub f64);

/// Energy in joules.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Joules(pub f64);

/// Wall-clock duration in milliseconds.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct Millis(pub f64);

/// The paper's headline figure of merit: output tokens per watt
/// (equivalently tokens per joule·s⁻¹·W⁻¹; numerically tok/s ÷ W).
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct TokensPerWatt(pub f64);

/// Memory size in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Bytes(pub u64);

impl Watts {
    pub fn kw(self) -> f64 {
        self.0 / 1e3
    }
    /// Energy spent holding this power for `secs` seconds.
    pub fn for_secs(self, secs: f64) -> Joules {
        Joules(self.0 * secs)
    }
}

impl Joules {
    pub fn kwh(self) -> f64 {
        self.0 / 3.6e6
    }
}

impl Millis {
    pub fn secs(self) -> f64 {
        self.0 / 1e3
    }
}

impl Bytes {
    pub const KB: u64 = 1_000;
    pub const MB: u64 = 1_000_000;
    pub const GB: u64 = 1_000_000_000;

    pub fn gb(self) -> f64 {
        self.0 as f64 / Self::GB as f64
    }
    pub fn from_gb(gb: f64) -> Self {
        Bytes((gb * Self::GB as f64) as u64)
    }
}

impl fmt::Display for Watts {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.0} W", self.0)
    }
}

impl fmt::Display for TokensPerWatt {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 10.0 {
            write!(f, "{:.1}", self.0)
        } else {
            write!(f, "{:.2}", self.0)
        }
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0 as f64;
        if b >= 1e9 {
            write!(f, "{:.1} GB", b / 1e9)
        } else if b >= 1e6 {
            write!(f, "{:.1} MB", b / 1e6)
        } else if b >= 1e3 {
            write!(f, "{:.1} KB", b / 1e3)
        } else {
            write!(f, "{} B", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watt_seconds_are_joules() {
        assert_eq!(Watts(500.0).for_secs(2.0).0, 1000.0);
    }

    #[test]
    fn kwh_conversion() {
        assert!((Joules(3.6e6).kwh() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bytes_display() {
        assert_eq!(Bytes(55_000).to_string(), "55.0 KB");
        assert_eq!(Bytes::from_gb(60.0).to_string(), "60.0 GB");
    }
}
