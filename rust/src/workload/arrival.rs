//! Streaming arrival sources: lazy request generators fused into the
//! event engine.
//!
//! Before this module, every simulation materialized its whole trace as
//! a `Vec<Request>` up front (`workload::synth::generate`), so memory —
//! not the zero-allocation event loop — capped λ·duration. An
//! [`ArrivalSource`] is an iterator the engine pulls **one arrival at a
//! time**: only the next pending request lives in the event queue, so
//! trace memory is O(1) at any scale (a 10⁷-arrival run holds exactly
//! one `Request`, where the materialized path would hold ~320 MB).
//!
//! Sources must yield arrivals **non-decreasing in `arrival_s`** — the
//! engine asserts this and the calendar queue depends on it (no
//! backward pushes). The concrete sources:
//!
//! - [`SynthSource`] — the stationary Poisson generator, a verbatim
//!   port of `synth::generate`'s loop. Same seed → bit-identical
//!   requests, so the materialized path stays a replay oracle.
//! - [`DiurnalSource`] — nonhomogeneous Poisson with a sinusoidal
//!   λ(t) (Lewis–Shedler thinning): the daily traffic curve a real
//!   fleet sees, compressed into the run duration.
//! - [`FlashCrowdSource`] — stationary base rate with a λ×magnitude
//!   burst window: the incident-traffic / product-launch archetype.
//! - [`MultiTenantSource`] — a weighted mix of chat (LMSYS), agent
//!   and conversation (Azure) tenants sharing one arrival process,
//!   each request drawing lengths from its tenant's distributions.
//! - [`HeavyTailSource`] — the base prompt CDF with its upper tail
//!   replaced by a Pareto graft: rare very-long-context requests that
//!   stress the long pool far beyond the empirical CDF's support.
//! - [`CsvSource`] — replay of a real trace from disk, streamed line
//!   by line (two passes over the file: validate then iterate), so
//!   replaying a million-row production trace is also O(1) memory.
//! - [`VecSource`] — adapter over an in-memory `Vec<Request>`, for
//!   tests and hand-built traces.
//! - [`ChannelSource`] — adapter over a bounded `mpsc` receiver: the
//!   per-group feed of the sharded parallel streaming path
//!   (`sim::events`), where a demux thread routes arrivals into small
//!   per-group buffers and each group's engine pulls from its own
//!   channel.
//!
//! [`ArrivalSpec`] is the CLI/scenario-facing selector that names an
//! archetype (`--workload diurnal`, `--trace requests.csv`, …) and
//! builds the matching source for a given workload + [`GenConfig`].

use std::fs::File;
use std::io::{BufRead, BufReader, Lines};
use std::path::Path;

use super::cdf::WorkloadTrace;
use super::synth::GenConfig;
use super::trace::Request;
use crate::xrand::Rng;

/// A lazy, non-decreasing stream of [`Request`]s.
///
/// The engine (`sim::events::run_fleet_stream`) pulls one arrival at a
/// time and keeps only that single pending request in its event queue.
/// Implementors must yield `arrival_s` values that never decrease; the
/// engine panics on a backward step (the calendar queue forbids
/// backward pushes).
pub trait ArrivalSource: Iterator<Item = Request> {
    /// Expected mean gap between arrivals in seconds, used to seed the
    /// calendar queue's bucket width (the streaming analogue of
    /// `trace_bucket_width`). Bucket width only affects queue
    /// performance, never event order, so a rough hint is fine.
    fn gap_hint(&self) -> f64 {
        1.0
    }
}

/// `ln`-space mean so that `E[lognormal(mu, sigma)] = mean_output_tokens`
/// — identical to the prelude of `synth::generate`.
fn output_mu(workload: &WorkloadTrace) -> f64 {
    workload.mean_output_tokens.ln() - workload.output_sigma * workload.output_sigma / 2.0
}

/// Draw (prompt, output) token counts exactly the way `synth::generate`
/// does: one CDF inverse-transform draw, then a two-draw Box–Muller
/// lognormal. Every source that claims bitwise compatibility with the
/// materialized generator must consume RNG draws in this order.
fn draw_lengths(workload: &WorkloadTrace, cfg: &GenConfig, mu: f64, rng: &mut Rng) -> (u32, u32) {
    let prompt = workload
        .prompt_cdf
        .sample(rng)
        .round()
        .max(1.0)
        .min(cfg.max_prompt_tokens as f64) as u32;
    let output = rng
        .lognormal(mu, workload.output_sigma)
        .round()
        .max(1.0)
        .min(cfg.max_output_tokens as f64) as u32;
    (prompt, output)
}

fn rate_gap_hint(lambda_rps: f64) -> f64 {
    if lambda_rps > 0.0 && lambda_rps.is_finite() {
        1.0 / lambda_rps
    } else {
        1.0
    }
}

// ---------------------------------------------------------------------------
// Stationary synthetic source (the generate() port)
// ---------------------------------------------------------------------------

/// Stationary Poisson arrivals with workload-drawn lengths — the lazy
/// form of [`synth::generate`](super::synth::generate). Same workload,
/// config and seed produce the bit-identical request sequence; the
/// materialized generator is now a `collect()` of this source.
pub struct SynthSource {
    workload: WorkloadTrace,
    cfg: GenConfig,
    rng: Rng,
    t: f64,
    id: u64,
    mu: f64,
}

impl SynthSource {
    pub fn new(workload: &WorkloadTrace, cfg: &GenConfig) -> Self {
        SynthSource {
            workload: workload.clone(),
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed),
            t: 0.0,
            id: 0,
            mu: output_mu(workload),
        }
    }
}

impl Iterator for SynthSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.t += self.rng.exp(self.cfg.lambda_rps);
        assert!(
            self.t.is_finite(),
            "non-finite arrival time generated (λ = {}, t = {})",
            self.cfg.lambda_rps,
            self.t
        );
        if self.t > self.cfg.duration_s {
            return None;
        }
        let (prompt, output) = draw_lengths(&self.workload, &self.cfg, self.mu, &mut self.rng);
        let req = Request {
            id: self.id,
            arrival_s: self.t,
            prompt_tokens: prompt,
            output_tokens: output,
        };
        self.id += 1;
        Some(req)
    }
}

impl ArrivalSource for SynthSource {
    fn gap_hint(&self) -> f64 {
        rate_gap_hint(self.cfg.lambda_rps)
    }
}

// ---------------------------------------------------------------------------
// Diurnal (sinusoidal λ) source — Lewis–Shedler thinning
// ---------------------------------------------------------------------------

/// Nonhomogeneous Poisson arrivals with
/// `λ(t) = λ·(1 − amplitude·cos(2πt/period))`: the trough sits at
/// t = 0, the peak at half a period, and the *mean* rate over a whole
/// period is exactly `cfg.lambda_rps`. Sampled by Lewis–Shedler
/// thinning against `λ_max = λ·(1 + amplitude)`.
pub struct DiurnalSource {
    workload: WorkloadTrace,
    cfg: GenConfig,
    rng: Rng,
    t: f64,
    id: u64,
    mu: f64,
    amplitude: f64,
    period_s: f64,
    lambda_max: f64,
}

impl DiurnalSource {
    /// `amplitude` ∈ [0, 1): peak-to-mean swing. `period_s <= 0`
    /// means one full cycle per run (`cfg.duration_s`).
    pub fn new(workload: &WorkloadTrace, cfg: &GenConfig, amplitude: f64, period_s: f64) -> Self {
        assert!(
            (0.0..1.0).contains(&amplitude),
            "diurnal amplitude must be in [0, 1), got {amplitude}"
        );
        let period = if period_s > 0.0 { period_s } else { cfg.duration_s };
        DiurnalSource {
            workload: workload.clone(),
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed),
            t: 0.0,
            id: 0,
            mu: output_mu(workload),
            amplitude,
            period_s: period,
            lambda_max: cfg.lambda_rps * (1.0 + amplitude),
        }
    }

    fn rate_at(&self, t: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t / self.period_s;
        self.cfg.lambda_rps * (1.0 - self.amplitude * phase.cos())
    }
}

impl Iterator for DiurnalSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        loop {
            self.t += self.rng.exp(self.lambda_max);
            assert!(
                self.t.is_finite(),
                "non-finite arrival time generated (λ_max = {}, t = {})",
                self.lambda_max,
                self.t
            );
            if self.t > self.cfg.duration_s {
                return None;
            }
            // Thinning: accept with probability λ(t)/λ_max.
            if self.rng.f64() * self.lambda_max >= self.rate_at(self.t) {
                continue;
            }
            let (prompt, output) = draw_lengths(&self.workload, &self.cfg, self.mu, &mut self.rng);
            let req = Request {
                id: self.id,
                arrival_s: self.t,
                prompt_tokens: prompt,
                output_tokens: output,
            };
            self.id += 1;
            return Some(req);
        }
    }
}

impl ArrivalSource for DiurnalSource {
    fn gap_hint(&self) -> f64 {
        rate_gap_hint(self.cfg.lambda_rps)
    }
}

// ---------------------------------------------------------------------------
// Flash-crowd source
// ---------------------------------------------------------------------------

/// Stationary base rate λ with one burst window at `λ·magnitude` —
/// an incident / launch-day traffic spike. Thinned against
/// `λ·magnitude` so the burst window accepts every candidate.
pub struct FlashCrowdSource {
    workload: WorkloadTrace,
    cfg: GenConfig,
    rng: Rng,
    t: f64,
    id: u64,
    mu: f64,
    burst_start: f64,
    burst_end: f64,
    magnitude: f64,
    lambda_max: f64,
}

impl FlashCrowdSource {
    /// Burst of `magnitude`× the base rate starting at
    /// `at_frac·duration` and lasting `width_frac·duration`.
    pub fn new(
        workload: &WorkloadTrace,
        cfg: &GenConfig,
        at_frac: f64,
        width_frac: f64,
        magnitude: f64,
    ) -> Self {
        assert!(
            magnitude >= 1.0,
            "flash-crowd magnitude must be >= 1, got {magnitude}"
        );
        assert!(
            (0.0..=1.0).contains(&at_frac) && (0.0..=1.0).contains(&width_frac),
            "flash-crowd window fractions must be in [0, 1], got at={at_frac} width={width_frac}"
        );
        let burst_start = at_frac * cfg.duration_s;
        let burst_end = (at_frac + width_frac).min(1.0) * cfg.duration_s;
        FlashCrowdSource {
            workload: workload.clone(),
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed),
            t: 0.0,
            id: 0,
            mu: output_mu(workload),
            burst_start,
            burst_end,
            magnitude,
            lambda_max: cfg.lambda_rps * magnitude,
        }
    }

    fn rate_at(&self, t: f64) -> f64 {
        if t >= self.burst_start && t < self.burst_end {
            self.cfg.lambda_rps * self.magnitude
        } else {
            self.cfg.lambda_rps
        }
    }
}

impl Iterator for FlashCrowdSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        loop {
            self.t += self.rng.exp(self.lambda_max);
            assert!(
                self.t.is_finite(),
                "non-finite arrival time generated (λ_max = {}, t = {})",
                self.lambda_max,
                self.t
            );
            if self.t > self.cfg.duration_s {
                return None;
            }
            if self.rng.f64() * self.lambda_max >= self.rate_at(self.t) {
                continue;
            }
            let (prompt, output) = draw_lengths(&self.workload, &self.cfg, self.mu, &mut self.rng);
            let req = Request {
                id: self.id,
                arrival_s: self.t,
                prompt_tokens: prompt,
                output_tokens: output,
            };
            self.id += 1;
            return Some(req);
        }
    }
}

impl ArrivalSource for FlashCrowdSource {
    fn gap_hint(&self) -> f64 {
        rate_gap_hint(self.cfg.lambda_rps)
    }
}

// ---------------------------------------------------------------------------
// Multi-tenant mix source
// ---------------------------------------------------------------------------

/// One stationary arrival process shared by several tenants; each
/// request picks a tenant by weight and draws its lengths from that
/// tenant's prompt CDF and output distribution. The fixed mix is
/// 50% chat (LMSYS), 30% agent (Agent-heavy), 20% conversation
/// (Azure) — the base workload passed to [`ArrivalSpec::source`] is
/// ignored (the mix *is* the workload).
pub struct MultiTenantSource {
    /// (tenant workload, cumulative weight, precomputed output mu).
    tenants: Vec<(WorkloadTrace, f64, f64)>,
    cfg: GenConfig,
    rng: Rng,
    t: f64,
    id: u64,
}

impl MultiTenantSource {
    pub fn new(cfg: &GenConfig) -> Self {
        let mix = [
            (super::cdf::lmsys_chat(), 0.5),
            (super::cdf::agent_heavy(), 0.3),
            (super::cdf::azure_conversations(), 0.2),
        ];
        let mut cum = 0.0;
        let tenants = mix
            .into_iter()
            .map(|(w, weight)| {
                cum += weight;
                let mu = output_mu(&w);
                (w, cum, mu)
            })
            .collect();
        MultiTenantSource {
            tenants,
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed),
            t: 0.0,
            id: 0,
        }
    }
}

impl Iterator for MultiTenantSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.t += self.rng.exp(self.cfg.lambda_rps);
        assert!(
            self.t.is_finite(),
            "non-finite arrival time generated (λ = {}, t = {})",
            self.cfg.lambda_rps,
            self.t
        );
        if self.t > self.cfg.duration_s {
            return None;
        }
        let u = self.rng.f64();
        let last = self.tenants.len() - 1;
        let ti = self
            .tenants
            .iter()
            .position(|(_, cum, _)| u < *cum)
            .unwrap_or(last);
        let (workload, _, mu) = &self.tenants[ti];
        let (prompt, output) = draw_lengths(workload, &self.cfg, *mu, &mut self.rng);
        let req = Request {
            id: self.id,
            arrival_s: self.t,
            prompt_tokens: prompt,
            output_tokens: output,
        };
        self.id += 1;
        Some(req)
    }
}

impl ArrivalSource for MultiTenantSource {
    fn gap_hint(&self) -> f64 {
        rate_gap_hint(self.cfg.lambda_rps)
    }
}

// ---------------------------------------------------------------------------
// Heavy-tail source
// ---------------------------------------------------------------------------

/// The base workload with the top `tail_frac` of its prompt CDF
/// replaced by a Pareto(α) graft anchored at the (1 − tail_frac)
/// quantile: rare requests far longer than the empirical CDF's
/// support, which is what actually stresses the long-context pool.
pub struct HeavyTailSource {
    workload: WorkloadTrace,
    cfg: GenConfig,
    rng: Rng,
    t: f64,
    id: u64,
    mu: f64,
    tail_frac: f64,
    alpha: f64,
    x_min: f64,
}

impl HeavyTailSource {
    pub fn new(workload: &WorkloadTrace, cfg: &GenConfig, tail_frac: f64, alpha: f64) -> Self {
        assert!(
            tail_frac > 0.0 && tail_frac < 1.0,
            "heavy-tail fraction must be in (0, 1), got {tail_frac}"
        );
        assert!(alpha > 1.0, "Pareto alpha must be > 1, got {alpha}");
        let x_min = workload.prompt_cdf.quantile(1.0 - tail_frac).max(1.0);
        HeavyTailSource {
            workload: workload.clone(),
            cfg: cfg.clone(),
            rng: Rng::new(cfg.seed),
            t: 0.0,
            id: 0,
            mu: output_mu(workload),
            tail_frac,
            alpha,
            x_min,
        }
    }
}

impl Iterator for HeavyTailSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.t += self.rng.exp(self.cfg.lambda_rps);
        assert!(
            self.t.is_finite(),
            "non-finite arrival time generated (λ = {}, t = {})",
            self.cfg.lambda_rps,
            self.t
        );
        if self.t > self.cfg.duration_s {
            return None;
        }
        let in_tail = self.rng.f64() < self.tail_frac;
        let prompt = if in_tail {
            // Pareto inverse transform: x_min · U^(−1/α), U ∈ (0, 1].
            let u = 1.0 - self.rng.f64();
            (self.x_min * u.powf(-1.0 / self.alpha))
                .round()
                .max(1.0)
                .min(self.cfg.max_prompt_tokens as f64) as u32
        } else {
            self.workload
                .prompt_cdf
                .sample(&mut self.rng)
                .round()
                .max(1.0)
                .min(self.cfg.max_prompt_tokens as f64) as u32
        };
        let output = self
            .rng
            .lognormal(self.mu, self.workload.output_sigma)
            .round()
            .max(1.0)
            .min(self.cfg.max_output_tokens as f64) as u32;
        let req = Request {
            id: self.id,
            arrival_s: self.t,
            prompt_tokens: prompt,
            output_tokens: output,
        };
        self.id += 1;
        Some(req)
    }
}

impl ArrivalSource for HeavyTailSource {
    fn gap_hint(&self) -> f64 {
        rate_gap_hint(self.cfg.lambda_rps)
    }
}

// ---------------------------------------------------------------------------
// CSV replay source
// ---------------------------------------------------------------------------

/// Streams a CSV trace from disk one row at a time.
///
/// `open` makes a validation pass over the whole file first (every row
/// parses, arrivals are non-decreasing, errors carry line numbers) and
/// records the row count and time span, then reopens the file for the
/// lazy iteration pass. Both passes are line-buffered, so replaying a
/// million-row trace never holds more than one row in memory.
pub struct CsvSource {
    lines: Lines<BufReader<File>>,
    path: String,
    lineno: usize,
    prev_arrival: f64,
    rows: usize,
    span_s: f64,
    gap: f64,
}

impl CsvSource {
    pub fn open(path: &Path) -> crate::Result<Self> {
        let shown = path.display().to_string();
        let file = File::open(path)
            .map_err(|e| anyhow::anyhow!("cannot open trace {shown}: {e}"))?;
        let mut rows = 0usize;
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        let mut prev = f64::NEG_INFINITY;
        for (i, line) in BufReader::new(file).lines().enumerate() {
            let line = line.map_err(|e| anyhow::anyhow!("read error in {shown}: {e}"))?;
            if i == 0 || line.trim().is_empty() {
                continue;
            }
            let req = super::trace::parse_row(&line, i + 1)
                .map_err(|e| anyhow::anyhow!("{shown}: {e}"))?;
            anyhow::ensure!(
                req.arrival_s >= prev,
                "{shown}: line {}: arrival_s {} goes backwards (previous row was {})",
                i + 1,
                req.arrival_s,
                prev
            );
            prev = req.arrival_s;
            if rows == 0 {
                first = req.arrival_s;
            }
            last = req.arrival_s;
            rows += 1;
        }
        let span = if rows >= 2 { last - first } else { 0.0 };
        let gap = if rows >= 2 {
            let g = span / (rows - 1) as f64;
            if g.is_finite() && g > 0.0 {
                g
            } else {
                1.0
            }
        } else {
            1.0
        };
        let file = File::open(path)
            .map_err(|e| anyhow::anyhow!("cannot reopen trace {shown}: {e}"))?;
        Ok(CsvSource {
            lines: BufReader::new(file).lines(),
            path: shown,
            lineno: 0,
            prev_arrival: f64::NEG_INFINITY,
            rows,
            span_s: span,
            gap,
        })
    }

    /// Number of request rows found during validation.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Time span (last − first arrival) of the trace in seconds.
    pub fn span_s(&self) -> f64 {
        self.span_s
    }

    /// Mean arrival rate of the trace, for deriving a λ when the CLI
    /// was not given one explicitly.
    pub fn mean_rate_rps(&self) -> f64 {
        if self.span_s > 0.0 {
            self.rows as f64 / self.span_s
        } else {
            self.rows as f64
        }
    }
}

impl Iterator for CsvSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        loop {
            let line = self.lines.next()?.unwrap_or_else(|e| {
                panic!("read error in {}: {e} (file changed after validation?)", self.path)
            });
            self.lineno += 1;
            if self.lineno == 1 || line.trim().is_empty() {
                continue;
            }
            let req = super::trace::parse_row(&line, self.lineno).unwrap_or_else(|e| {
                panic!("{}: {e} (file changed after validation?)", self.path)
            });
            assert!(
                req.arrival_s >= self.prev_arrival,
                "{}: line {}: arrival_s goes backwards (file changed after validation?)",
                self.path,
                self.lineno
            );
            self.prev_arrival = req.arrival_s;
            return Some(req);
        }
    }
}

impl ArrivalSource for CsvSource {
    fn gap_hint(&self) -> f64 {
        self.gap
    }
}

// ---------------------------------------------------------------------------
// In-memory source (tests, hand-built traces)
// ---------------------------------------------------------------------------

/// Streams an already-materialized trace — the adapter that lets a
/// hand-built `Vec<Request>` drive the streaming engine (tests, and
/// the replay half of the bitwise oracle).
pub struct VecSource {
    gap: f64,
    iter: std::vec::IntoIter<Request>,
}

impl VecSource {
    /// `trace` must already be sorted by arrival time (the engine
    /// asserts it).
    pub fn new(trace: Vec<Request>) -> Self {
        let gap = if trace.len() < 2 {
            1.0
        } else {
            let span = trace[trace.len() - 1].arrival_s - trace[0].arrival_s;
            let g = span / (trace.len() - 1) as f64;
            if g.is_finite() && g > 0.0 {
                g
            } else {
                1.0
            }
        };
        VecSource {
            gap,
            iter: trace.into_iter(),
        }
    }
}

impl Iterator for VecSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.iter.next()
    }
}

impl ArrivalSource for VecSource {
    fn gap_hint(&self) -> f64 {
        self.gap
    }
}

// ---------------------------------------------------------------------------
// Channel-fed source (the sharded parallel streaming path)
// ---------------------------------------------------------------------------

/// Streams requests out of a bounded [`std::sync::mpsc`] channel — the
/// per-group arrival feed of the sharded parallel streaming path. A
/// demux thread routes each pulled arrival to its owning group and
/// sends it over that group's `SyncSender`; the group's engine runs
/// `run_fleet_stream` over this source exactly as it would over any
/// other. The iterator ends when the sender side hangs up, so the
/// demux dropping its senders is the end-of-trace signal.
///
/// Blocking `recv` gives backpressure for free: a group that runs
/// ahead of the demux parks until its next arrival is routed, and the
/// bounded send side parks the demux when a group falls behind —
/// memory stays O(channel capacity) per group regardless of trace
/// length.
pub struct ChannelSource {
    rx: std::sync::mpsc::Receiver<Request>,
    gap: f64,
}

impl ChannelSource {
    /// `gap` seeds the group's calendar-queue bucket width; pass the
    /// demuxed source's [`gap_hint`](ArrivalSource::gap_hint) (the
    /// per-group gap is wider, but bucket width only affects queue
    /// performance, never event order).
    pub fn new(rx: std::sync::mpsc::Receiver<Request>, gap: f64) -> Self {
        let gap = if gap.is_finite() && gap > 0.0 { gap } else { 1.0 };
        ChannelSource { rx, gap }
    }
}

impl Iterator for ChannelSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.rx.recv().ok()
    }
}

impl ArrivalSource for ChannelSource {
    fn gap_hint(&self) -> f64 {
        self.gap
    }
}

// ---------------------------------------------------------------------------
// ArrivalSpec — the scenario/CLI-facing selector
// ---------------------------------------------------------------------------

/// Names an arrival process for a scenario: the stationary default,
/// one of the generated archetypes, or replay of a CSV trace. Carried
/// on `ScenarioSpec` and selected on the CLI via `--workload <name>`
/// or `--trace <path.csv>`.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Stationary Poisson arrivals (the historical behavior).
    Stationary,
    /// Sinusoidal λ(t); `period_s <= 0` means one cycle per run.
    Diurnal { amplitude: f64, period_s: f64 },
    /// One burst window at `magnitude`× the base rate.
    FlashCrowd {
        at_frac: f64,
        width_frac: f64,
        magnitude: f64,
    },
    /// Fixed chat/agent/conversation tenant mix on one arrival stream.
    MultiTenant,
    /// Pareto graft on the top `tail_frac` of the prompt CDF.
    HeavyTail { tail_frac: f64, alpha: f64 },
    /// Replay a CSV trace from disk.
    Replay { path: String },
}

impl Default for ArrivalSpec {
    fn default() -> Self {
        ArrivalSpec::Stationary
    }
}

impl ArrivalSpec {
    /// The generated archetype names accepted by `--workload`.
    pub const NAMES: [&'static str; 5] = [
        "stationary",
        "diurnal",
        "flash-crowd",
        "multi-tenant",
        "heavy-tail",
    ];

    /// Parse a `--workload` archetype name with its default
    /// parameters. Returns `None` for unknown names (the CLI turns
    /// that into an error listing [`Self::NAMES`]).
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "stationary" => Some(ArrivalSpec::Stationary),
            "diurnal" => Some(ArrivalSpec::Diurnal {
                amplitude: 0.6,
                period_s: 0.0,
            }),
            "flash-crowd" => Some(ArrivalSpec::FlashCrowd {
                at_frac: 0.5,
                width_frac: 0.1,
                magnitude: 5.0,
            }),
            "multi-tenant" => Some(ArrivalSpec::MultiTenant),
            "heavy-tail" => Some(ArrivalSpec::HeavyTail {
                tail_frac: 0.05,
                alpha: 1.5,
            }),
            _ => None,
        }
    }

    /// Short human label used in scenario/sweep workload columns.
    pub fn label(&self) -> String {
        match self {
            ArrivalSpec::Stationary => "stationary".to_string(),
            ArrivalSpec::Diurnal { amplitude, .. } => format!("diurnal(a={amplitude})"),
            ArrivalSpec::FlashCrowd { magnitude, .. } => format!("flash-crowd(x{magnitude})"),
            ArrivalSpec::MultiTenant => "multi-tenant".to_string(),
            ArrivalSpec::HeavyTail { tail_frac, alpha } => {
                format!("heavy-tail({tail_frac},α={alpha})")
            }
            ArrivalSpec::Replay { path } => {
                let name = Path::new(path)
                    .file_name()
                    .map(|n| n.to_string_lossy().into_owned())
                    .unwrap_or_else(|| path.clone());
                format!("replay:{name}")
            }
        }
    }

    /// Build the arrival source this spec describes for a given base
    /// workload and generator config. Only `Replay` can fail (I/O or
    /// a malformed trace file).
    pub fn source(
        &self,
        workload: &WorkloadTrace,
        gen: &GenConfig,
    ) -> crate::Result<Box<dyn ArrivalSource>> {
        Ok(match self {
            ArrivalSpec::Stationary => Box::new(SynthSource::new(workload, gen)),
            ArrivalSpec::Diurnal {
                amplitude,
                period_s,
            } => Box::new(DiurnalSource::new(workload, gen, *amplitude, *period_s)),
            ArrivalSpec::FlashCrowd {
                at_frac,
                width_frac,
                magnitude,
            } => Box::new(FlashCrowdSource::new(
                workload,
                gen,
                *at_frac,
                *width_frac,
                *magnitude,
            )),
            ArrivalSpec::MultiTenant => Box::new(MultiTenantSource::new(gen)),
            ArrivalSpec::HeavyTail { tail_frac, alpha } => {
                Box::new(HeavyTailSource::new(workload, gen, *tail_frac, *alpha))
            }
            ArrivalSpec::Replay { path } => Box::new(CsvSource::open(Path::new(path))?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::azure_conversations;

    fn gen(lambda: f64, duration: f64, seed: u64) -> GenConfig {
        GenConfig {
            lambda_rps: lambda,
            duration_s: duration,
            max_prompt_tokens: 60_000,
            max_output_tokens: 512,
            seed,
        }
    }

    fn collect(src: impl ArrivalSource) -> Vec<Request> {
        src.collect()
    }

    #[test]
    fn synth_source_matches_materialized_generate_bitwise() {
        let w = azure_conversations();
        let cfg = gen(200.0, 2.0, 7);
        let materialized = super::super::synth::generate(&w, &cfg);
        let streamed = collect(SynthSource::new(&w, &cfg));
        assert_eq!(materialized.len(), streamed.len());
        for (a, b) in materialized.iter().zip(streamed.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
        }
    }

    #[test]
    fn synth_source_is_lazy() {
        // λ·duration = 10^7 expected arrivals: taking 100 must be
        // instant and never materialize the rest.
        let w = azure_conversations();
        let cfg = gen(1_000_000.0, 10.0, 1);
        let first: Vec<Request> = SynthSource::new(&w, &cfg).take(100).collect();
        assert_eq!(first.len(), 100);
        for pair in first.windows(2) {
            assert!(pair[1].arrival_s >= pair[0].arrival_s);
        }
    }

    #[test]
    fn every_archetype_yields_sorted_finite_arrivals() {
        let w = azure_conversations();
        let cfg = gen(500.0, 2.0, 3);
        let sources: Vec<(&str, Vec<Request>)> = vec![
            ("synth", collect(SynthSource::new(&w, &cfg))),
            ("diurnal", collect(DiurnalSource::new(&w, &cfg, 0.6, 0.0))),
            (
                "flash",
                collect(FlashCrowdSource::new(&w, &cfg, 0.5, 0.1, 5.0)),
            ),
            ("tenant", collect(MultiTenantSource::new(&cfg))),
            ("tail", collect(HeavyTailSource::new(&w, &cfg, 0.05, 1.5))),
        ];
        for (name, reqs) in &sources {
            assert!(!reqs.is_empty(), "{name}: empty trace");
            for pair in reqs.windows(2) {
                assert!(
                    pair[1].arrival_s >= pair[0].arrival_s,
                    "{name}: arrivals not sorted"
                );
            }
            for r in reqs {
                assert!(r.arrival_s.is_finite(), "{name}: non-finite arrival");
                assert!(r.prompt_tokens >= 1 && r.output_tokens >= 1, "{name}: zero tokens");
                assert!(r.arrival_s <= cfg.duration_s, "{name}: arrival past horizon");
            }
            // ids must be dense 0..n for the engine's Arrival events.
            for (i, r) in reqs.iter().enumerate() {
                assert_eq!(r.id, i as u64, "{name}: non-dense ids");
            }
        }
    }

    #[test]
    fn diurnal_peak_quarter_beats_trough_quarter() {
        let w = azure_conversations();
        let cfg = gen(2000.0, 2.0, 11);
        // One cycle per run: trough at t=0, peak at duration/2.
        let reqs = collect(DiurnalSource::new(&w, &cfg, 0.6, 0.0));
        let q = cfg.duration_s / 4.0;
        let trough = reqs.iter().filter(|r| r.arrival_s < q).count();
        let peak = reqs
            .iter()
            .filter(|r| r.arrival_s >= 1.5 * q && r.arrival_s < 2.5 * q)
            .count();
        assert!(
            peak as f64 > 2.0 * trough as f64,
            "peak quarter ({peak}) should far exceed trough quarter ({trough})"
        );
    }

    #[test]
    fn flash_crowd_burst_window_is_denser() {
        let w = azure_conversations();
        let cfg = gen(1000.0, 2.0, 13);
        let reqs = collect(FlashCrowdSource::new(&w, &cfg, 0.5, 0.1, 5.0));
        let burst_start = 0.5 * cfg.duration_s;
        let burst_end = 0.6 * cfg.duration_s;
        let width = burst_end - burst_start;
        let in_burst = reqs
            .iter()
            .filter(|r| r.arrival_s >= burst_start && r.arrival_s < burst_end)
            .count();
        let before = reqs.iter().filter(|r| r.arrival_s < width).count();
        assert!(
            in_burst as f64 > 2.0 * before as f64,
            "burst window ({in_burst}) should be much denser than baseline ({before})"
        );
    }

    #[test]
    fn heavy_tail_p99_exceeds_base_p99() {
        let w = azure_conversations();
        let cfg = gen(2000.0, 2.0, 17);
        let mut base: Vec<u32> = collect(SynthSource::new(&w, &cfg))
            .iter()
            .map(|r| r.prompt_tokens)
            .collect();
        let mut tail: Vec<u32> = collect(HeavyTailSource::new(&w, &cfg, 0.05, 1.2))
            .iter()
            .map(|r| r.prompt_tokens)
            .collect();
        base.sort_unstable();
        tail.sort_unstable();
        let p99 = |v: &[u32]| v[(v.len() as f64 * 0.99) as usize - 1];
        assert!(
            p99(&tail) > p99(&base),
            "heavy-tail p99 {} should exceed base p99 {}",
            p99(&tail),
            p99(&base)
        );
    }

    #[test]
    fn archetypes_are_deterministic_in_seed() {
        let w = azure_conversations();
        let cfg = gen(500.0, 1.0, 23);
        let a = collect(DiurnalSource::new(&w, &cfg, 0.6, 0.0));
        let b = collect(DiurnalSource::new(&w, &cfg, 0.6, 0.0));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
    }

    #[test]
    fn csv_source_streams_a_saved_trace() {
        let w = azure_conversations();
        let cfg = gen(100.0, 1.0, 29);
        let trace = super::super::synth::generate(&w, &cfg);
        let path = std::env::temp_dir().join("wattlaw_arrival_csv_roundtrip.csv");
        super::super::trace::save_csv(&path, &trace).unwrap();
        let mut src = CsvSource::open(&path).unwrap();
        assert_eq!(src.rows(), trace.len());
        assert!(src.span_s() > 0.0);
        assert!(src.mean_rate_rps() > 0.0);
        let replayed: Vec<Request> = (&mut src).collect();
        assert_eq!(replayed.len(), trace.len());
        for (a, b) in trace.iter().zip(replayed.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            // CSV stores 6 decimal places — compare at that precision.
            assert!((a.arrival_s - b.arrival_s).abs() < 1e-6);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_source_rejects_backwards_time_with_line_number() {
        let path = std::env::temp_dir().join("wattlaw_arrival_csv_backwards.csv");
        std::fs::write(
            &path,
            "id,arrival_s,prompt_tokens,output_tokens\n0,1.0,10,10\n1,0.5,10,10\n",
        )
        .unwrap();
        let err = CsvSource::open(&path).unwrap_err().to_string();
        assert!(err.contains("line 3"), "error should name line 3: {err}");
        assert!(err.contains("backwards"), "error should say backwards: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_source_rejects_malformed_rows_with_line_number() {
        let path = std::env::temp_dir().join("wattlaw_arrival_csv_malformed.csv");
        std::fs::write(
            &path,
            "id,arrival_s,prompt_tokens,output_tokens\n0,0.5,10\n",
        )
        .unwrap();
        let err = CsvSource::open(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "error should name line 2: {err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_trace_file_is_a_clean_error() {
        let err = CsvSource::open(Path::new("/nonexistent/wattlaw_nope.csv"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot open trace"), "got: {err}");
    }

    #[test]
    fn spec_parse_round_trips_all_names() {
        for name in ArrivalSpec::NAMES {
            let spec = ArrivalSpec::parse(name).expect(name);
            assert!(!spec.label().is_empty());
        }
        assert!(ArrivalSpec::parse("bogus").is_none());
        assert_eq!(ArrivalSpec::default(), ArrivalSpec::Stationary);
    }

    #[test]
    fn spec_builds_a_source_for_every_generated_archetype() {
        let w = azure_conversations();
        let cfg = gen(300.0, 0.5, 31);
        for name in ArrivalSpec::NAMES {
            let spec = ArrivalSpec::parse(name).unwrap();
            let src = spec.source(&w, &cfg).expect(name);
            let n = src.count();
            assert!(n > 0, "{name}: no arrivals");
        }
    }

    #[test]
    fn replay_label_uses_the_file_name() {
        let spec = ArrivalSpec::Replay {
            path: "/tmp/some/dir/prod_trace.csv".to_string(),
        };
        assert_eq!(spec.label(), "replay:prod_trace.csv");
    }
}
