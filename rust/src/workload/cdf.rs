//! Context-length CDFs for the paper's workloads.
//!
//! The real traces (Azure LLM Inference Trace, LMSYS-Chat-1M) are not
//! redistributable in this offline image, so — per the substitution rule —
//! each is encoded as a piecewise log-linear CDF matched to the statistics
//! the paper states and the traces' published summary shape:
//!
//! * **Azure Conversations**: "89 % of requests fit within 4K tokens";
//!   long tail to 128K; mean output ≈ 325 tokens (implied by Table 3's
//!   λ·L̄_out accounting).
//! * **LMSYS-Chat-1M**: chat-style short prompts; the paper's two-pool
//!   split sits at B_short = 1.5K; mean output ≈ 136 tokens.
//! * **Agent-heavy** (§7): "74 % of requests fit within 8K, the remaining
//!   26 % extend to 64K (p99 ≈ 32K)".
//!
//! The fleet model consumes only (a) pool traffic fractions at a split
//! boundary, (b) conditional mean lengths, (c) samples — all of which the
//! piecewise CDF provides exactly and deterministically.

use crate::xrand::Rng;

/// Piecewise log-linear length CDF: `points` are (tokens, cumulative
/// probability), strictly increasing in both coordinates, ending at 1.0.
/// Between breakpoints the CDF is interpolated linearly in log2(tokens) —
/// the natural scale for context lengths.
#[derive(Debug, Clone)]
pub struct LengthCdf {
    points: Vec<(f64, f64)>,
    min_tokens: f64,
}

impl LengthCdf {
    pub fn new(min_tokens: f64, points: Vec<(f64, f64)>) -> Self {
        assert!(!points.is_empty());
        assert!(min_tokens > 0.0);
        for w in points.windows(2) {
            assert!(w[0].0 < w[1].0, "tokens must increase");
            assert!(w[0].1 <= w[1].1, "CDF must be monotone");
        }
        let last = points.last().unwrap();
        assert!((last.1 - 1.0).abs() < 1e-9, "CDF must end at 1.0");
        LengthCdf { points, min_tokens }
    }

    pub fn max_tokens(&self) -> f64 {
        self.points.last().unwrap().0
    }

    /// P(length ≤ t).
    pub fn frac_leq(&self, t: f64) -> f64 {
        if t <= self.min_tokens {
            return 0.0;
        }
        if t >= self.max_tokens() {
            return 1.0;
        }
        let lt = t.log2();
        let mut prev = (self.min_tokens, 0.0);
        for &(x, p) in &self.points {
            if t <= x {
                let l0 = prev.0.log2();
                let l1 = x.log2();
                let f = (lt - l0) / (l1 - l0);
                return prev.1 + f * (p - prev.1);
            }
            prev = (x, p);
        }
        1.0
    }

    /// Inverse CDF.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..=1.0).contains(&p));
        let mut prev = (self.min_tokens, 0.0);
        for &(x, q) in &self.points {
            if p <= q {
                if q == prev.1 {
                    return x;
                }
                let f = (p - prev.1) / (q - prev.1);
                let l = prev.0.log2() + f * (x.log2() - prev.0.log2());
                return l.exp2();
            }
            prev = (x, q);
        }
        self.max_tokens()
    }

    /// Draw one length.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        self.quantile(rng.f64())
    }

    /// Mean of the distribution restricted to lengths in (lo, hi],
    /// computed by numerical quadrature over the quantile function
    /// (exact enough at 4096 steps for every consumer in the crate).
    pub fn conditional_mean(&self, lo: f64, hi: f64) -> f64 {
        let p_lo = self.frac_leq(lo);
        let p_hi = self.frac_leq(hi);
        if p_hi - p_lo < 1e-12 {
            return 0.5 * (lo + hi.min(self.max_tokens()));
        }
        let steps = 4096;
        let mut acc = 0.0;
        for i in 0..steps {
            let p = p_lo + (p_hi - p_lo) * (i as f64 + 0.5) / steps as f64;
            acc += self.quantile(p);
        }
        acc / steps as f64
    }

    /// Unconditional mean length.
    pub fn mean(&self) -> f64 {
        self.conditional_mean(0.0, self.max_tokens())
    }
}

/// Workload archetypes from paper Table 6.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Archetype {
    /// >80 % of traffic ≤ 8K tokens (Azure-like).
    ShortDominant,
    /// 50–80 % ≤ 8K.
    Mixed,
    /// <50 % ≤ 8K.
    LongDominant,
}

/// A named workload: prompt-length CDF plus output-length statistics.
#[derive(Debug, Clone)]
pub struct WorkloadTrace {
    pub name: &'static str,
    pub prompt_cdf: LengthCdf,
    /// Mean output (decode) length, tokens.
    pub mean_output_tokens: f64,
    /// Lognormal sigma for output-length sampling.
    pub output_sigma: f64,
    /// The paper's two-pool split boundary for this trace, tokens.
    pub paper_b_short: u32,
}

impl WorkloadTrace {
    pub fn archetype(&self) -> Archetype {
        let f8k = self.prompt_cdf.frac_leq(8192.0);
        if f8k > 0.80 {
            Archetype::ShortDominant
        } else if f8k >= 0.50 {
            Archetype::Mixed
        } else {
            Archetype::LongDominant
        }
    }
}

/// Azure LLM Inference ("Conversations") — short-dominant. 89 % ≤ 4K.
pub fn azure_conversations() -> WorkloadTrace {
    WorkloadTrace {
        name: "Azure",
        prompt_cdf: LengthCdf::new(
            16.0,
            vec![
                (256.0, 0.20),
                (512.0, 0.35),
                (1024.0, 0.52),
                (2048.0, 0.74),
                (4096.0, 0.89),
                (8192.0, 0.95),
                (16384.0, 0.975),
                (32768.0, 0.990),
                (65536.0, 0.997),
                (131072.0, 1.0),
            ],
        ),
        mean_output_tokens: 325.0,
        output_sigma: 0.9,
        paper_b_short: 4096,
    }
}

/// LMSYS-Chat-1M — chatbot traffic, even shorter prompts.
pub fn lmsys_chat() -> WorkloadTrace {
    WorkloadTrace {
        name: "LMSYS",
        prompt_cdf: LengthCdf::new(
            8.0,
            vec![
                (128.0, 0.25),
                (256.0, 0.45),
                (512.0, 0.65),
                (1024.0, 0.80),
                (1536.0, 0.86),
                (2048.0, 0.90),
                (4096.0, 0.96),
                (8192.0, 0.990),
                (16384.0, 0.998),
                (65536.0, 1.0),
            ],
        ),
        mean_output_tokens: 136.0,
        output_sigma: 0.8,
        paper_b_short: 1536,
    }
}

/// Agent-heavy (§7): dispersed lengths; 74 % ≤ 8K, p99 ≈ 32K.
pub fn agent_heavy() -> WorkloadTrace {
    WorkloadTrace {
        name: "Agent-heavy",
        prompt_cdf: LengthCdf::new(
            64.0,
            vec![
                (1024.0, 0.10),
                (2048.0, 0.25),
                (4096.0, 0.50),
                (8192.0, 0.74),
                (16384.0, 0.88),
                (32768.0, 0.990),
                (65536.0, 1.0),
            ],
        ),
        mean_output_tokens: 512.0,
        output_sigma: 0.7,
        paper_b_short: 8192,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn azure_matches_paper_statistics() {
        let t = azure_conversations();
        let f4k = t.prompt_cdf.frac_leq(4096.0);
        assert!((f4k - 0.89).abs() < 0.005, "89% <= 4K, got {f4k}");
        assert_eq!(t.archetype(), Archetype::ShortDominant);
    }

    #[test]
    fn agent_heavy_matches_section7() {
        let t = agent_heavy();
        let f8k = t.prompt_cdf.frac_leq(8192.0);
        assert!((f8k - 0.74).abs() < 0.005, "74% <= 8K, got {f8k}");
        let p99 = t.prompt_cdf.quantile(0.99);
        assert!(
            (25_000.0..=40_000.0).contains(&p99),
            "p99 ≈ 32K, got {p99}"
        );
    }

    #[test]
    fn lmsys_is_short_dominant_with_1_5k_split() {
        let t = lmsys_chat();
        assert_eq!(t.paper_b_short, 1536);
        assert!(t.prompt_cdf.frac_leq(1536.0) > 0.8);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let t = azure_conversations();
        for p in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let x = t.prompt_cdf.quantile(p);
            let back = t.prompt_cdf.frac_leq(x);
            assert!((back - p).abs() < 1e-6, "p={p}: x={x}, back={back}");
        }
    }

    #[test]
    fn cdf_is_monotone_everywhere() {
        let t = azure_conversations();
        let mut prev = 0.0;
        let mut x = 16.0;
        while x < 131_072.0 {
            let f = t.prompt_cdf.frac_leq(x);
            assert!(f >= prev);
            prev = f;
            x *= 1.1;
        }
    }

    #[test]
    fn conditional_means_ordered() {
        let t = azure_conversations();
        let short = t.prompt_cdf.conditional_mean(0.0, 4096.0);
        let long = t.prompt_cdf.conditional_mean(4096.0, 131_072.0);
        let all = t.prompt_cdf.mean();
        assert!(short < all && all < long, "{short} < {all} < {long}");
        assert!(short < 4096.0 && long > 4096.0);
    }

    #[test]
    fn samples_follow_cdf() {
        let t = lmsys_chat();
        let mut rng = crate::xrand::Rng::new(99);
        let n = 50_000;
        let below: usize = (0..n)
            .filter(|_| t.prompt_cdf.sample(&mut rng) <= 1536.0)
            .count();
        let frac = below as f64 / n as f64;
        assert!((frac - 0.86).abs() < 0.01, "sampled frac = {frac}");
    }
}
