//! Workload modeling: context-length CDFs for the paper's traces
//! ([`cdf`]), synthetic request generation with Poisson arrivals
//! ([`synth`]), trace records with CSV I/O ([`trace`]), and lazy
//! streaming arrival sources — stationary, diurnal, flash-crowd,
//! multi-tenant, heavy-tailed, and CSV replay — that the event engine
//! pulls one request at a time ([`arrival`]).

pub mod arrival;
pub mod cdf;
pub mod synth;
pub mod trace;

pub use arrival::{
    ArrivalSource, ArrivalSpec, ChannelSource, CsvSource, SynthSource, VecSource,
};
pub use cdf::{LengthCdf, WorkloadTrace, Archetype};
pub use trace::Request;
