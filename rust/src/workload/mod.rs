//! Workload modeling: context-length CDFs for the paper's traces
//! ([`cdf`]), synthetic request generation with Poisson arrivals
//! ([`synth`]), and trace records with CSV I/O ([`trace`]).

pub mod cdf;
pub mod synth;
pub mod trace;

pub use cdf::{LengthCdf, WorkloadTrace, Archetype};
pub use trace::Request;
