//! Synthetic request generation: Poisson arrivals at rate λ with prompt
//! lengths drawn from a trace CDF and output lengths from a lognormal
//! matched to the trace's mean — the steady-state traffic model the
//! paper's fleet sizing assumes (§10.1 "Steady-state traffic").

use super::cdf::WorkloadTrace;
use super::trace::Request;
use crate::xrand::Rng;

/// Generator configuration. `PartialEq` so consumers can detect when two
/// scenarios would generate byte-identical traces.
#[derive(Debug, Clone, PartialEq)]
pub struct GenConfig {
    /// Arrival rate, requests/second (the paper's fleets use λ = 1000).
    pub lambda_rps: f64,
    /// Trace duration, seconds.
    pub duration_s: f64,
    /// Cap on prompt length (the serving context window minus headroom
    /// for output); longer samples are clamped.
    pub max_prompt_tokens: u32,
    /// Cap on output length.
    pub max_output_tokens: u32,
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig {
            lambda_rps: 1000.0,
            duration_s: 60.0,
            max_prompt_tokens: 131_072,
            max_output_tokens: 4096,
            seed: 0,
        }
    }
}

/// Generate a deterministic request trace, materialized as a `Vec`.
///
/// This loop is deliberately kept as an independent implementation:
/// [`arrival::SynthSource`](super::arrival::SynthSource) is its lazy
/// streaming port, and the bitwise-equivalence test in `arrival` pins
/// the two against each other (same seed → identical requests), so
/// this function doubles as the materialized oracle for the streaming
/// path. Scenario code streams by default and only calls this when it
/// genuinely needs the whole trace in memory.
pub fn generate(trace: &WorkloadTrace, cfg: &GenConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;

    // Lognormal(mu, sigma) with mean = mean_output_tokens:
    // mean = exp(mu + sigma^2/2)  =>  mu = ln(mean) - sigma^2/2.
    let sigma = trace.output_sigma;
    let mu = trace.mean_output_tokens.ln() - sigma * sigma / 2.0;

    loop {
        t += rng.exp(cfg.lambda_rps);
        // The exponential sampler can only produce finite positive gaps,
        // but a corrupt λ or duration would poison every downstream
        // consumer that orders by arrival (the simulator sorts with
        // `total_cmp` and rejects non-finite arrivals) — fail here, at
        // the source, instead.
        assert!(
            t.is_finite(),
            "non-finite arrival time generated (λ = {}, t = {t})",
            cfg.lambda_rps
        );
        if t > cfg.duration_s {
            break;
        }
        let prompt = trace
            .prompt_cdf
            .sample(&mut rng)
            .round()
            .max(1.0)
            .min(cfg.max_prompt_tokens as f64) as u32;
        let output = rng
            .lognormal(mu, sigma)
            .round()
            .max(1.0)
            .min(cfg.max_output_tokens as f64) as u32;
        out.push(Request {
            id,
            arrival_s: t,
            prompt_tokens: prompt,
            output_tokens: output,
        });
        id += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::cdf::{azure_conversations, lmsys_chat};

    #[test]
    fn arrival_rate_matches_lambda() {
        let cfg = GenConfig {
            lambda_rps: 500.0,
            duration_s: 20.0,
            seed: 1,
            ..Default::default()
        };
        let reqs = generate(&azure_conversations(), &cfg);
        let rate = reqs.len() as f64 / cfg.duration_s;
        assert!(
            (rate - 500.0).abs() / 500.0 < 0.05,
            "empirical rate = {rate}"
        );
    }

    #[test]
    fn arrivals_sorted_and_in_range() {
        let cfg = GenConfig {
            lambda_rps: 100.0,
            duration_s: 5.0,
            seed: 2,
            ..Default::default()
        };
        let reqs = generate(&lmsys_chat(), &cfg);
        for w in reqs.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
        assert!(reqs.iter().all(|r| r.arrival_s <= 5.0 && r.arrival_s > 0.0));
    }

    #[test]
    fn deterministic_in_seed() {
        let cfg = GenConfig { seed: 7, duration_s: 2.0, ..Default::default() };
        let a = generate(&azure_conversations(), &cfg);
        let b = generate(&azure_conversations(), &cfg);
        assert_eq!(a, b);
        let c = generate(
            &azure_conversations(),
            &GenConfig { seed: 8, duration_s: 2.0, ..Default::default() },
        );
        assert_ne!(a, c);
    }

    #[test]
    fn output_mean_close_to_trace_mean() {
        let cfg = GenConfig {
            lambda_rps: 2000.0,
            duration_s: 30.0,
            seed: 3,
            ..Default::default()
        };
        let trace = azure_conversations();
        let reqs = generate(&trace, &cfg);
        let mean: f64 = reqs.iter().map(|r| r.output_tokens as f64).sum::<f64>()
            / reqs.len() as f64;
        assert!(
            (mean - trace.mean_output_tokens).abs() / trace.mean_output_tokens
                < 0.08,
            "mean output = {mean}"
        );
    }

    #[test]
    fn clamps_respected() {
        let cfg = GenConfig {
            lambda_rps: 1000.0,
            duration_s: 5.0,
            max_prompt_tokens: 2048,
            max_output_tokens: 64,
            seed: 4,
        };
        let reqs = generate(&azure_conversations(), &cfg);
        assert!(reqs.iter().all(|r| r.prompt_tokens <= 2048));
        assert!(reqs.iter().all(|r| r.output_tokens <= 64));
        assert!(reqs.iter().all(|r| r.prompt_tokens >= 1 && r.output_tokens >= 1));
    }
}
