//! Request records and CSV trace I/O.

use std::fmt::Write as _;
use std::path::Path;

/// One inference request as the router/simulator/serving engine see it.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length, tokens.
    pub prompt_tokens: u32,
    /// Output (decode) length, tokens.
    pub output_tokens: u32,
}

impl Request {
    /// Total KV footprint the request reaches at completion.
    pub fn total_tokens(&self) -> u32 {
        self.prompt_tokens + self.output_tokens
    }
}

/// Serialize a trace to CSV (header + one row per request).
pub fn to_csv(reqs: &[Request]) -> String {
    let mut s = String::with_capacity(reqs.len() * 32 + 64);
    s.push_str("id,arrival_s,prompt_tokens,output_tokens\n");
    for r in reqs {
        let _ = writeln!(
            s,
            "{},{:.6},{},{}",
            r.id, r.arrival_s, r.prompt_tokens, r.output_tokens
        );
    }
    s
}

/// Parse one data row of a CSV trace, with the 1-based source line
/// number threaded into every error message. Rejects non-finite or
/// negative arrival times and zero-token requests — a zero-output
/// request would never complete and a non-finite arrival corrupts the
/// event queue, so both are trace bugs worth naming at the line.
pub(crate) fn parse_row(line: &str, lineno: usize) -> crate::Result<Request> {
    let mut f = line.split(',');
    let mut next = |what: &str| {
        f.next()
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: missing {what}"))
    };
    let field = |what: &str, v: &str| {
        anyhow::anyhow!("line {lineno}: bad {what} {v:?}")
    };
    let id_s = next("id")?;
    let id: u64 = id_s.trim().parse().map_err(|_| field("id", id_s))?;
    let arr_s = next("arrival_s")?;
    let arrival_s: f64 = arr_s
        .trim()
        .parse()
        .map_err(|_| field("arrival_s", arr_s))?;
    anyhow::ensure!(
        arrival_s.is_finite() && arrival_s >= 0.0,
        "line {lineno}: arrival_s must be finite and >= 0, got {arrival_s}"
    );
    let p_s = next("prompt_tokens")?;
    let prompt_tokens: u32 = p_s
        .trim()
        .parse()
        .map_err(|_| field("prompt_tokens", p_s))?;
    let o_s = next("output_tokens")?;
    let output_tokens: u32 = o_s
        .trim()
        .parse()
        .map_err(|_| field("output_tokens", o_s))?;
    anyhow::ensure!(
        prompt_tokens >= 1 && output_tokens >= 1,
        "line {lineno}: zero-token request (prompt = {prompt_tokens}, output = {output_tokens})"
    );
    Ok(Request {
        id,
        arrival_s,
        prompt_tokens,
        output_tokens,
    })
}

/// Parse a CSV trace produced by [`to_csv`]. Every row must parse,
/// arrivals must be non-decreasing, and errors carry line numbers.
pub fn from_csv(text: &str) -> crate::Result<Vec<Request>> {
    let mut out: Vec<Request> = Vec::new();
    let mut prev = f64::NEG_INFINITY;
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue; // header / blank
        }
        let req = parse_row(line, i + 1)?;
        anyhow::ensure!(
            req.arrival_s >= prev,
            "line {}: arrival_s {} goes backwards (previous row was {})",
            i + 1,
            req.arrival_s,
            prev
        );
        prev = req.arrival_s;
        out.push(req);
    }
    Ok(out)
}

/// Write a trace to disk.
pub fn save_csv(path: &Path, reqs: &[Request]) -> crate::Result<()> {
    std::fs::write(path, to_csv(reqs))?;
    Ok(())
}

/// Load a trace from disk.
pub fn load_csv(path: &Path) -> crate::Result<Vec<Request>> {
    from_csv(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Request> {
        vec![
            Request { id: 0, arrival_s: 0.0, prompt_tokens: 100, output_tokens: 50 },
            Request { id: 1, arrival_s: 0.5, prompt_tokens: 9000, output_tokens: 300 },
        ]
    }

    #[test]
    fn csv_roundtrip() {
        let reqs = sample();
        let parsed = from_csv(&to_csv(&reqs)).unwrap();
        assert_eq!(parsed, reqs);
    }

    #[test]
    fn total_tokens() {
        assert_eq!(sample()[1].total_tokens(), 9300);
    }

    #[test]
    fn malformed_line_is_error() {
        assert!(from_csv("id,arrival_s,prompt_tokens,output_tokens\n1,2.0\n").is_err());
    }

    #[test]
    fn blank_lines_skipped() {
        let txt = "id,arrival_s,prompt_tokens,output_tokens\n\n0,0.0,1,1\n\n";
        assert_eq!(from_csv(txt).unwrap().len(), 1);
    }

    const HDR: &str = "id,arrival_s,prompt_tokens,output_tokens\n";

    #[test]
    fn missing_field_error_names_the_line() {
        let err = from_csv(&format!("{HDR}0,0.0,10,5\n1,2.0\n"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 3"), "got: {err}");
    }

    #[test]
    fn unparseable_field_error_names_the_line_and_field() {
        let err = from_csv(&format!("{HDR}0,0.0,ten,5\n"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "got: {err}");
        assert!(err.contains("prompt_tokens"), "got: {err}");
    }

    #[test]
    fn non_monotonic_arrival_error_names_the_line() {
        let err = from_csv(&format!("{HDR}0,1.0,10,5\n1,0.5,10,5\n"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 3"), "got: {err}");
        assert!(err.contains("backwards"), "got: {err}");
    }

    #[test]
    fn zero_token_request_error_names_the_line() {
        let err = from_csv(&format!("{HDR}0,0.0,10,0\n"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 2"), "got: {err}");
        assert!(err.contains("zero-token"), "got: {err}");
    }

    #[test]
    fn non_finite_or_negative_arrival_is_error() {
        for bad in ["nan", "inf", "-1.0"] {
            let err = from_csv(&format!("{HDR}0,{bad},10,5\n"))
                .unwrap_err()
                .to_string();
            assert!(err.contains("line 2"), "{bad}: {err}");
        }
    }
}
