//! Request records and CSV trace I/O.

use std::fmt::Write as _;
use std::path::Path;

/// One inference request as the router/simulator/serving engine see it.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    /// Prompt length, tokens.
    pub prompt_tokens: u32,
    /// Output (decode) length, tokens.
    pub output_tokens: u32,
}

impl Request {
    /// Total KV footprint the request reaches at completion.
    pub fn total_tokens(&self) -> u32 {
        self.prompt_tokens + self.output_tokens
    }
}

/// Serialize a trace to CSV (header + one row per request).
pub fn to_csv(reqs: &[Request]) -> String {
    let mut s = String::with_capacity(reqs.len() * 32 + 64);
    s.push_str("id,arrival_s,prompt_tokens,output_tokens\n");
    for r in reqs {
        let _ = writeln!(
            s,
            "{},{:.6},{},{}",
            r.id, r.arrival_s, r.prompt_tokens, r.output_tokens
        );
    }
    s
}

/// Parse a CSV trace produced by [`to_csv`].
pub fn from_csv(text: &str) -> crate::Result<Vec<Request>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue; // header / blank
        }
        let mut f = line.split(',');
        let mut next = |what: &str| {
            f.next()
                .ok_or_else(|| anyhow::anyhow!("line {}: missing {what}", i + 1))
        };
        let id = next("id")?.trim().parse()?;
        let arrival_s = next("arrival_s")?.trim().parse()?;
        let prompt_tokens = next("prompt_tokens")?.trim().parse()?;
        let output_tokens = next("output_tokens")?.trim().parse()?;
        out.push(Request {
            id,
            arrival_s,
            prompt_tokens,
            output_tokens,
        });
    }
    Ok(out)
}

/// Write a trace to disk.
pub fn save_csv(path: &Path, reqs: &[Request]) -> crate::Result<()> {
    std::fs::write(path, to_csv(reqs))?;
    Ok(())
}

/// Load a trace from disk.
pub fn load_csv(path: &Path) -> crate::Result<Vec<Request>> {
    from_csv(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Request> {
        vec![
            Request { id: 0, arrival_s: 0.0, prompt_tokens: 100, output_tokens: 50 },
            Request { id: 1, arrival_s: 0.5, prompt_tokens: 9000, output_tokens: 300 },
        ]
    }

    #[test]
    fn csv_roundtrip() {
        let reqs = sample();
        let parsed = from_csv(&to_csv(&reqs)).unwrap();
        assert_eq!(parsed, reqs);
    }

    #[test]
    fn total_tokens() {
        assert_eq!(sample()[1].total_tokens(), 9300);
    }

    #[test]
    fn malformed_line_is_error() {
        assert!(from_csv("id,arrival_s,prompt_tokens,output_tokens\n1,2.0\n").is_err());
    }

    #[test]
    fn blank_lines_skipped() {
        let txt = "id,arrival_s,prompt_tokens,output_tokens\n\n0,0.0,1,1\n\n";
        assert_eq!(from_csv(txt).unwrap().len(), 1);
    }
}
