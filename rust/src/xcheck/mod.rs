//! `xcheck` — a minimal property-based testing framework.
//!
//! `proptest`/`quickcheck` are not fetchable in this offline image, so this
//! module provides the subset the test suites need: seeded generators,
//! a `forall` runner that reports the failing seed and case number, and
//! shrink-lite (on failure, retry with scaled-down numeric inputs to report
//! a smaller counterexample when one exists).
//!
//! ```no_run
//! use wattlaw::xcheck::forall;
//! use wattlaw::xcheck_assert;
//! forall("addition commutes", 200, |g| {
//!     let a = g.f64_in(0.0, 1e6);
//!     let b = g.f64_in(0.0, 1e6);
//!     xcheck_assert!(a + b == b + a, "a={a} b={b}");
//!     Ok(())
//! });
//! ```

use crate::xrand::Rng;

/// Property-case outcome.
pub type CaseResult = Result<(), String>;

/// Generator handle passed to each property case.
pub struct Gen {
    rng: Rng,
    /// Shrink factor in (0, 1]; 1 = full-range generation. During
    /// shrinking retries the ranges contract toward their lower bound.
    shrink: f64,
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, shrink: f64) -> Self {
        Gen { rng: Rng::new(seed), shrink, log: Vec::new() }
    }

    fn note(&mut self, what: &str, v: impl std::fmt::Display) {
        if self.log.len() < 64 {
            self.log.push(format!("{what}={v}"));
        }
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let hi_eff = lo + (hi - lo) * self.shrink;
        let v = lo + self.rng.f64() * (hi_eff - lo);
        self.note("f64", v);
        v
    }

    /// Uniform u64 in [lo, hi] inclusive.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        let hi_eff = lo + (((hi - lo) as f64) * self.shrink) as u64;
        let v = self.rng.range_u64(lo, hi_eff.max(lo));
        self.note("u64", v);
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    /// Uniform u32 power of two in [2^lo_exp, 2^hi_exp].
    pub fn pow2(&mut self, lo_exp: u32, hi_exp: u32) -> u32 {
        let e = self.u64_in(lo_exp as u64, hi_exp as u64) as u32;
        let v = 1u32 << e;
        self.note("pow2", v);
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.f64() < 0.5;
        self.note("bool", v);
        v
    }

    /// Pick one element.
    pub fn choose<'a, T: std::fmt::Debug>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range_usize(0, xs.len() - 1);
        let v = &xs[i];
        self.note("choose", format!("{v:?}"));
        v
    }

    /// Access the raw RNG (for domain-specific sampling).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics with seed + generated-value
/// log on the first failure (after attempting shrink retries).
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> CaseResult,
{
    // Honor XCHECK_SEED for reproducing failures.
    let base_seed = std::env::var("XCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_0000u64);

    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let mut g = Gen::new(seed, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink-lite: retry the same seed with contracted ranges and
            // report the smallest still-failing configuration.
            let mut best: Option<(f64, String, Vec<String>)> = None;
            for &s in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let mut gs = Gen::new(seed, s);
                if let Err(m2) = prop(&mut gs) {
                    best = Some((s, m2, gs.log.clone()));
                }
            }
            let (shrink, fmsg, flog) = best
                .map(|(s, m, l)| (s, m, l))
                .unwrap_or((1.0, msg, g.log.clone()));
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, \
                 shrink {shrink}):\n  {fmsg}\n  inputs: [{}]\n  \
                 reproduce with XCHECK_SEED={seed}",
                flog.join(", ")
            );
        }
    }
}

/// Assertion macro for property bodies: returns `Err(String)` instead of
/// panicking so the runner can shrink and report.
#[macro_export]
macro_rules! xcheck_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, |g| {
            let _ = g.f64_in(0.0, 1.0);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_context() {
        forall("always fails", 10, |g| {
            let x = g.f64_in(0.0, 100.0);
            xcheck_assert!(x < 0.0, "x = {x} is not negative");
            #[allow(unreachable_code)]
            Ok(())
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall("bounds", 200, |g| {
            let f = g.f64_in(5.0, 6.0);
            xcheck_assert!((5.0..6.0).contains(&f), "f={f}");
            let u = g.u64_in(10, 20);
            xcheck_assert!((10..=20).contains(&u), "u={u}");
            let p = g.pow2(3, 10);
            xcheck_assert!(p.is_power_of_two() && (8..=1024).contains(&p), "p={p}");
            Ok(())
        });
    }
}
