//! Deterministic PRNG (no external crates are fetchable in this offline
//! image, so `rand` is replaced by a small, well-tested SplitMix64 +
//! xoshiro256** implementation).
//!
//! Every stochastic component in the crate (workload generation, the
//! discrete-event simulator, property tests) threads one of these through
//! explicitly, so every experiment is reproducible from a single seed.

/// xoshiro256** seeded via SplitMix64 — the reference parameterization.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread a small seed over the full state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        lo + (self.f64() * span as f64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Used for Poisson
    /// inter-arrival times.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(17);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 6);
            assert!((3..=6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
