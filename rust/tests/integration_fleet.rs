//! Cross-module integration: workload CDFs → topology → queueing-based
//! sizing → Eq. 4 analysis → optimizer, and the analytical-vs-simulated
//! consistency loop.

use std::sync::Arc;

use wattlaw::fleet::analysis::fleet_tpw_analysis;
use wattlaw::fleet::optimizer::{optimize_fleetopt, multi_pool};
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use wattlaw::fleet::topology::{Topology, LONG_CTX};
use wattlaw::power::Gpu;
use wattlaw::router::context::ContextRouter;
use wattlaw::router::HomogeneousRouter;
use wattlaw::sim::{simulate_topology, GroupSimConfig};
use wattlaw::workload::cdf::{agent_heavy, azure_conversations, lmsys_chat};
use wattlaw::workload::synth::{generate, GenConfig};

fn h100() -> Arc<dyn GpuProfile> {
    Arc::new(ManualProfile::h100_70b())
}

#[test]
fn full_planning_pipeline_all_traces_all_gpus() {
    for trace in [azure_conversations(), lmsys_chat(), agent_heavy()] {
        for gpu in Gpu::ALL {
            let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::for_gpu(gpu));
            let b = trace.paper_b_short;
            for topo in [
                Topology::Homogeneous { ctx: LONG_CTX },
                Topology::PoolRouting { b_short: b, short_ctx: b.max(2048) },
                Topology::FleetOpt { b_short: b, short_ctx: b.max(2048), gamma: 2.0 },
            ] {
                let pools = topo.pools(
                    &trace, 1000.0, profile.clone(), None,
                    LBarPolicy::Window, 0.85, 0.5);
                let r = fleet_tpw_analysis(&pools, PowerAccounting::PerGpu);
                assert!(r.total_groups > 0, "{}/{gpu:?}/{}", trace.name, topo.label());
                assert!(r.tok_per_watt.0.is_finite() && r.tok_per_watt.0 > 0.0);
                // Every pool meets the TTFT SLO it was sized for.
                for p in &r.pools {
                    if p.lambda_rps > 0.0 {
                        assert!(
                            p.sizing.p99_ttft_s <= 0.5 + 1e-9,
                            "{}: P99 TTFT {}",
                            p.name,
                            p.sizing.p99_ttft_s
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn optimizer_beats_paper_default_or_ties() {
    let trace = azure_conversations();
    let best = optimize_fleetopt(
        &trace, 1000.0, h100(), LBarPolicy::Window, 0.85, 0.5,
        PowerAccounting::PerGpu);
    // The paper's operating point (B_short = 4K, γ = 2).
    let paper_pools = Topology::FleetOpt { b_short: 4096, short_ctx: 4096, gamma: 2.0 }
        .pools(&trace, 1000.0, h100(), None, LBarPolicy::Window, 0.85, 0.5);
    let paper = fleet_tpw_analysis(&paper_pools, PowerAccounting::PerGpu);
    assert!(
        best.report.tok_per_watt.0 >= paper.tok_per_watt.0 * 0.999,
        "optimum {} must be >= paper default {}",
        best.report.tok_per_watt.0,
        paper.tok_per_watt.0
    );
}

#[test]
fn simulated_tok_w_tracks_analytical_prediction_when_saturated() {
    // Size a small fleet analytically, then play a matching trace through
    // the simulator: the dynamic tok/W must land within a factor-2 band
    // of the analytical value (the analytical number assumes L̄ = window,
    // the simulator sees real lengths — DESIGN.md §4 explains the bias
    // direction: simulated >= analytical).
    let profile = ManualProfile::h100_70b();
    let window = 8192u32;
    let n_max = profile.n_max(window);
    let analytical = wattlaw::tokeconomy::operating_point(
        &profile, window, 0.85, PowerAccounting::PerGpu)
        .tok_per_watt
        .0;

    let reqs = generate(
        &azure_conversations(),
        &GenConfig {
            lambda_rps: 400.0,
            duration_s: 3.0,
            max_prompt_tokens: 7000,
            max_output_tokens: 512,
            seed: 3,
        },
    );
    let sim = simulate_topology(
        &reqs,
        &HomogeneousRouter,
        &[2],
        &[GroupSimConfig {
            window_tokens: window,
            n_max,
            roofline: profile.roofline(),
            power: profile.gpu.power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        }],
    );
    assert!(
        sim.tok_per_watt >= analytical * 0.9,
        "simulated {} must be >= ~analytical window-bound {}",
        sim.tok_per_watt,
        analytical
    );
    assert!(
        sim.tok_per_watt <= analytical * 8.0,
        "simulated {} suspiciously above analytical {}",
        sim.tok_per_watt,
        analytical
    );
}

#[test]
fn simulated_topology_gain_matches_analytical_direction() {
    let trace = generate(
        &azure_conversations(),
        &GenConfig {
            lambda_rps: 60.0,
            duration_s: 5.0,
            max_prompt_tokens: 60_000,
            max_output_tokens: 1024,
            seed: 17,
        },
    );
    let p = ManualProfile::h100_70b();
    let mk = |w: u32| GroupSimConfig {
        window_tokens: w,
        n_max: p.n_max(w),
        roofline: p.roofline(),
        power: p.gpu.power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    };
    let homo = simulate_topology(&trace, &HomogeneousRouter, &[4], &[mk(LONG_CTX)]);
    let routed = simulate_topology(
        &trace,
        &ContextRouter::two_pool(4096),
        &[2, 2],
        &[mk(4096 + 1024), mk(LONG_CTX)],
    );
    assert!(routed.tok_per_watt > homo.tok_per_watt);
    assert_eq!(routed.output_tokens, homo.output_tokens, "token conservation");
}

#[test]
fn three_tier_pipeline_end_to_end() {
    let trace = agent_heavy();
    let r = multi_pool(
        &trace, 1000.0, h100(), &[4096, 16_384, LONG_CTX],
        LBarPolicy::Window, 0.85, 0.5, PowerAccounting::PerGpu);
    assert_eq!(r.pools.len(), 3);
    let lam: f64 = r.pools.iter().map(|p| p.lambda_rps).sum();
    assert!((lam - 1000.0).abs() < 1e-6);
    // Tiers are ordered by efficiency (short window pools more efficient).
    assert!(r.pools[0].tok_per_watt.0 > r.pools[2].tok_per_watt.0);
}

#[test]
fn traffic_mean_lbar_is_more_optimistic_than_window() {
    let trace = azure_conversations();
    let mk = |lbar| {
        let pools = Topology::Homogeneous { ctx: LONG_CTX }.pools(
            &trace, 1000.0, h100(), None, lbar, 0.85, 0.5);
        fleet_tpw_analysis(&pools, PowerAccounting::PerGpu).tok_per_watt.0
    };
    assert!(mk(LBarPolicy::TrafficMean) > mk(LBarPolicy::Window));
}
