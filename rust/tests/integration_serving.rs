//! Serving-stack integration without PJRT: router → batcher + scheduler +
//! paged KV + energy metering, driven by a synthetic executor. (The real
//! PJRT path is covered by tests/runtime_roundtrip.rs.)

use wattlaw::power::LogisticPower;
use wattlaw::router::context::ContextRouter;
use wattlaw::router::fleetopt::FleetOptRouter;
use wattlaw::router::semantic::SemanticRouter;
use wattlaw::router::Router;
use wattlaw::serve::batcher::{Batcher, SlotWork};
use wattlaw::serve::energy::EnergyMeter;
use wattlaw::serve::kvblocks::BlockAllocator;
use wattlaw::serve::metrics::ServeMetrics;
use wattlaw::serve::request::ServeRequest;
use wattlaw::serve::scheduler::{schedule, SchedulerPolicy};
use wattlaw::workload::synth::{generate, GenConfig};
use wattlaw::workload::Request;

/// Drive a batcher with a fixed virtual step time, a scheduler policy and
/// an energy meter — a synthetic engine.
fn drive(
    batcher: &mut Batcher,
    policy: &SchedulerPolicy,
    step_s: f64,
) -> (ServeMetrics, EnergyMeter) {
    let mut metrics = ServeMetrics::default();
    let mut meter = EnergyMeter::new(LogisticPower::h100(), 1.0, 0.0);
    let mut t = 0.0;
    let mut guard = 0u64;
    while batcher.has_work() {
        batcher.admit(t);
        let plan = schedule(batcher, policy);
        let n = plan.iter().filter(|w| !matches!(w, SlotWork::Idle)).count();
        assert!(n > 0, "wedged");
        t += step_s;
        meter.observe(t, n as f64);
        for (i, w) in plan.into_iter().enumerate() {
            match w {
                SlotWork::Idle => {}
                SlotWork::Decode => {
                    meter.add_output_tokens(1);
                    if let Some(c) = batcher.on_step(i, SlotWork::Decode, t) {
                        metrics.record(&c);
                    }
                }
                ingest => {
                    batcher.on_step(i, ingest, t);
                }
            }
        }
        guard += 1;
        assert!(guard < 2_000_000, "runaway");
    }
    (metrics, meter)
}

fn requests(n: usize, seed: u64, max_prompt: u32) -> Vec<ServeRequest> {
    let reqs = generate(
        &wattlaw::workload::cdf::lmsys_chat(),
        &GenConfig {
            lambda_rps: 100.0,
            duration_s: 60.0,
            max_prompt_tokens: max_prompt,
            max_output_tokens: 128,
            seed,
        },
    );
    reqs.iter().take(n).map(ServeRequest::from).collect()
}

#[test]
fn synthetic_engine_completes_everything_and_accounts_energy() {
    let mut b = Batcher::new(16, BlockAllocator::new(64, 4096), 256, 8192);
    let reqs = requests(200, 1, 4000);
    let total_out: u64 = reqs.iter().map(|r| r.output_tokens as u64).sum();
    for mut r in reqs {
        r.arrival_s = 0.0;
        assert!(b.submit(r));
    }
    let (metrics, meter) = drive(&mut b, &SchedulerPolicy::default(), 0.02);
    assert_eq!(metrics.completed, 200);
    assert_eq!(meter.output_tokens(), total_out);
    assert!(meter.joules().0 > 0.0);
    assert_eq!(b.blocks.used(), 0, "all KV released");
}

#[test]
fn ingest_cap_slows_ttft_but_never_deadlocks() {
    let strict = SchedulerPolicy { max_ingest_slots: 1, ingest_fifo: true };
    let loose = SchedulerPolicy { max_ingest_slots: 16, ingest_fifo: true };
    let run = |policy: &SchedulerPolicy| {
        let mut b = Batcher::new(8, BlockAllocator::new(64, 2048), 128, 8192);
        for mut r in requests(40, 2, 3000) {
            r.arrival_s = 0.0;
            b.submit(r);
        }
        let (mut m, _) = drive(&mut b, policy, 0.02);
        (m.completed, m.ttft_s.p99())
    };
    let (done_strict, ttft_strict) = run(&strict);
    let (done_loose, ttft_loose) = run(&loose);
    assert_eq!(done_strict, 40);
    assert_eq!(done_loose, 40);
    assert!(
        ttft_strict >= ttft_loose,
        "capping ingest cannot improve TTFT tails: {ttft_strict} vs {ttft_loose}"
    );
}

#[test]
fn routers_partition_and_preserve_traffic() {
    let trace: Vec<Request> = generate(
        &wattlaw::workload::cdf::azure_conversations(),
        &GenConfig {
            lambda_rps: 500.0,
            duration_s: 4.0,
            max_prompt_tokens: 100_000,
            max_output_tokens: 512,
            seed: 9,
        },
    );

    for router in [
        Box::new(ContextRouter::two_pool(4096)) as Box<dyn Router>,
        Box::new(FleetOptRouter::new(4096, 2.0)),
        Box::new(SemanticRouter::new(0.35)),
    ] {
        let mut counts = vec![0usize; router.num_pools()];
        for r in &trace {
            let route = router.route(r);
            assert!(route.pool < router.num_pools(), "{}", router.name());
            assert!(route.effective_prompt_tokens >= 1);
            assert!(
                route.effective_prompt_tokens <= r.prompt_tokens,
                "routing may only shrink prompts ({})",
                router.name()
            );
            counts[route.pool] += 1;
        }
        let total: usize = counts.iter().sum();
        assert_eq!(total, trace.len(), "{}", router.name());
        assert!(
            counts.iter().all(|&c| c > 0),
            "{}: every pool sees traffic on Azure: {counts:?}",
            router.name()
        );
    }
}

#[test]
fn fleetopt_compression_lets_more_sequences_fit() {
    // 32 long requests through the FleetOpt router at γ=2: the compressed
    // prompts halve the KV footprint, so a fixed block budget admits ~2×
    // the concurrency vs. the uncompressed context router.
    let long_reqs: Vec<Request> = (0..32)
        .map(|i| Request {
            id: i,
            arrival_s: 0.0,
            prompt_tokens: 3_000,
            output_tokens: 50,
        })
        .collect();

    let concurrency = |router: &dyn Router| {
        let mut b = Batcher::new(32, BlockAllocator::new(64, 192), 512, 65_536);
        for r in &long_reqs {
            let route = router.route(r);
            let mut s = ServeRequest::from(r);
            s.prompt_tokens = route.effective_prompt_tokens;
            b.submit(s);
        }
        b.admit(0.0);
        b.active()
    };
    let plain = concurrency(&ContextRouter::two_pool(1024));
    let compressed = concurrency(&FleetOptRouter::new(1024, 2.0));
    assert!(
        compressed >= plain * 2 - 1,
        "γ=2 admits ~2×: {compressed} vs {plain}"
    );
}

#[test]
fn memory_pressure_stalls_then_recovers() {
    // Pool with room for exactly two full-window sequences.
    let mut b = Batcher::new(8, BlockAllocator::new(64, 16), 64, 512);
    for i in 0..6u64 {
        b.submit(ServeRequest {
            id: i,
            prompt_tokens: 400,
            output_tokens: 30,
            arrival_s: 0.0,
        });
    }
    let (metrics, _) = drive(&mut b, &SchedulerPolicy::default(), 0.01);
    assert_eq!(metrics.completed, 6, "stalled admissions eventually run");
}

#[test]
fn energy_meter_matches_closed_form_over_constant_load() {
    // n=8 held for exactly 1000 steps of 10 ms -> 10 s at P(8) = 369.4 W.
    let mut b = Batcher::new(8, BlockAllocator::new(64, 4096), 64, 4096);
    for i in 0..8u64 {
        b.submit(ServeRequest {
            id: i,
            prompt_tokens: 1, // join immediately
            output_tokens: 1000,
            arrival_s: 0.0,
        });
    }
    let (_, meter) = drive(&mut b, &SchedulerPolicy { max_ingest_slots: 8, ingest_fifo: false }, 0.01);
    // 1 ingest step + 1000 decode steps each. Mean batch ≈ 8 throughout.
    let expect_j = LogisticPower::h100().power_w(8.0) * meter.elapsed_s();
    assert!(
        (meter.joules().0 - expect_j).abs() / expect_j < 0.02,
        "J = {} vs closed-form {}",
        meter.joules().0,
        expect_j
    );
}
