//! Table-regeneration integration: every paper table/figure generates,
//! contains its structural landmarks, and the paper-vs-measured claim set
//! stays within its documented bands.

use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::report;
use wattlaw::tables;

#[test]
fn all_tables_generate_under_both_lbar_policies() {
    for lbar in [LBarPolicy::Window, LBarPolicy::TrafficMean] {
        let s = tables::generate_all(lbar);
        assert!(s.len() > 4000, "suspiciously small output: {}", s.len());
        for needle in [
            "Table 1", "Table 2", "Table 3", "Table 4", "Table 5", "Table 6",
            "Table 7", "1/W law", "independence",
        ] {
            assert!(s.contains(needle), "{lbar:?}: missing {needle}");
        }
    }
}

#[test]
fn table1_matches_paper_within_3_percent() {
    for (r, p) in tables::t1::rows().iter().zip(tables::t1::PAPER.iter()) {
        assert_eq!(r.context, p.0);
        assert!(((r.h100.tok_per_watt.0 - p.3) / p.3).abs() < 0.015);
        assert!(((r.b200.tok_per_watt.0 - p.6) / p.6).abs() < 0.03);
    }
}

#[test]
fn table3_reproduces_every_qualitative_ordering() {
    let rows = tables::t3::rows(LBarPolicy::Window);
    assert_eq!(rows.len(), 12);
    // Within each (trace, gpu) block: Homo < Pool < FleetOpt, and GPU
    // counts strictly decrease.
    for chunk in rows.chunks(3) {
        let [homo, pool, opt] = chunk else { panic!("chunking") };
        assert!(homo.report.tok_per_watt.0 < pool.report.tok_per_watt.0);
        assert!(pool.report.tok_per_watt.0 < opt.report.tok_per_watt.0);
        assert!(homo.report.total_groups > pool.report.total_groups);
        assert!(pool.report.total_groups >= opt.report.total_groups);
    }
}

#[test]
fn claims_report_within_bands() {
    // The same acceptance logic as the in-crate test, exercised through
    // the public API (this is what `wattlaw report` prints).
    for c in report::claims() {
        let band = match c.id {
            id if id.starts_with("T1/") => 0.03,
            id if id.starts_with("Gen/") => 0.05,
            id if id.starts_with("Law/") => 0.05,
            id if id.starts_with("Ind/") => 0.20,
            "T2/405B-rescue" => f64::INFINITY, // magnitude-only claim
            other => panic!("unknown claim {other}"),
        };
        assert!(
            c.rel_err() < band || band.is_infinite(),
            "{}: rel err {:.3} outside band {band}",
            c.id,
            c.rel_err()
        );
    }
    let s = report::paper_vs_measured();
    assert!(s.contains("Ind/multiplicative"));
}

#[test]
fn t6_recommendations_are_stable() {
    let rows = tables::t6::rows();
    assert_eq!(rows.len(), 3);
    // Regenerating must be deterministic.
    let again = tables::t6::rows();
    for (a, b) in rows.iter().zip(again.iter()) {
        assert_eq!(a.best_topology, b.best_topology);
        assert_eq!(a.best_gpu, b.best_gpu);
    }
}

#[test]
fn law_figure_statistics() {
    for (gpu, fit) in tables::law_fig::fits() {
        assert_eq!(fit.points.len(), 7);
        assert!(fit.spread > 30.0, "{gpu:?}");
        // Monotone decline of tok/W with context.
        for w in fit.points.windows(2) {
            assert!(w[0].tok_per_watt.0 > w[1].tok_per_watt.0);
        }
    }
}
