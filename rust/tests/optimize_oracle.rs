//! Regression oracles for the scenario-native optimizer.
//!
//! * Restricted to the legacy `B_SHORT_GRID × GAMMA_GRID`, stage A must
//!   rank the same best (B_short, γ) cell as the old closed-form
//!   `sweep_fleetopt` — and stage B must never crown an SLO-violating
//!   winner.
//! * **K=2 reduction**: the partition-native optimizer with two-entry
//!   cutoff vectors must reproduce the PR 3 two-pool `Topology::FleetOpt`
//!   ranking bit-identically through BOTH stages.
//! * The legacy `optimizer::multi_pool` closed form must agree with the
//!   K-pool `analyze()` path to 1e-12 on its own grids.
//! * Monotonicity: on a mixed-length workload the K=3 analytical winner
//!   beats the K=2 winner, and its stage-B simulated tok/W lands within
//!   ±15 % of the stage-A analytical value.

use std::sync::Arc;

use wattlaw::fleet::optimizer::{multi_pool, optimize_fleetopt, sweep_fleetopt};
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{
    GpuProfile, ManualProfile, ModelAxis, PowerAccounting,
};
use wattlaw::fleet::topology::{Topology, LONG_CTX};
use wattlaw::power::Gpu;
use wattlaw::scenario::optimize::{
    analyze_cell, kpool_partitions, optimize, screen, screen_mixed, GpuAxis,
    MixedScreen, OptimizeConfig, UpgradeBudget,
};
use wattlaw::scenario::{ScenarioSpec, SloTargets};
use wattlaw::workload::cdf::{
    agent_heavy, azure_conversations, lmsys_chat, WorkloadTrace,
};
use wattlaw::workload::synth::GenConfig;

fn h100() -> Arc<dyn GpuProfile> {
    Arc::new(ManualProfile::h100_70b())
}

/// The new stage-A screen, restricted to the legacy grid (the
/// `OptimizeConfig` default axes ARE the legacy grids), must agree with
/// the legacy closed-form sweep cell for cell — same winner, same
/// tok/W bits.
#[test]
fn stage_a_matches_legacy_sweep_on_the_legacy_grid() {
    let t = azure_conversations();
    let legacy = sweep_fleetopt(
        &t,
        1000.0,
        h100(),
        LBarPolicy::Window,
        0.85,
        0.5,
        PowerAccounting::PerGpu,
    );
    let cfg = OptimizeConfig { gpus: vec![Gpu::H100], ..Default::default() };
    let screened = screen(&t, &cfg);
    assert_eq!(screened.len(), legacy.len());
    // Same best cell, bit-identical analytical tok/W down the ranking.
    for (s, l) in screened.iter().zip(&legacy) {
        assert_eq!(s.b_short(), l.b_short);
        assert_eq!(s.cutoffs, vec![l.b_short, LONG_CTX]);
        assert_eq!(s.gamma, l.gamma);
        assert_eq!(
            s.analytic.tok_per_watt.0.to_bits(),
            l.report.tok_per_watt.0.to_bits()
        );
    }
}

#[test]
fn legacy_wrapper_still_finds_the_same_optimum() {
    // `optimize_fleetopt` (the old public API) now routes through the
    // scenario optimizer's screen; its contract is unchanged.
    let t = azure_conversations();
    let best = optimize_fleetopt(
        &t,
        1000.0,
        h100(),
        LBarPolicy::Window,
        0.85,
        0.5,
        PowerAccounting::PerGpu,
    );
    assert!(best.gamma > 1.0, "γ* = {}", best.gamma);
    let all = sweep_fleetopt(
        &t,
        1000.0,
        h100(),
        LBarPolicy::Window,
        0.85,
        0.5,
        PowerAccounting::PerGpu,
    );
    for r in &all {
        assert!(best.report.tok_per_watt.0 >= r.report.tok_per_watt.0);
    }
}

fn quick_cfg(slo_s: f64) -> OptimizeConfig {
    OptimizeConfig {
        gpus: vec![Gpu::H100],
        b_shorts: vec![2048, 4096],
        gammas: vec![1.0, 2.0],
        dispatches: vec!["rr".into(), "jsq".into()],
        gen: GenConfig {
            lambda_rps: 150.0,
            duration_s: 0.4,
            max_prompt_tokens: 20_000,
            max_output_tokens: 64,
            seed: 11,
        },
        groups: 2,
        slo: SloTargets { ttft_p99_s: slo_s },
        top_k: 2,
        ..Default::default()
    }
}

#[test]
fn stage_b_winner_is_measured_and_slo_clean() {
    let t = azure_conversations();
    let report = optimize(&t, &quick_cfg(1e3), 2);
    // top_k cells × 2 dispatch policies, each carrying both engines.
    assert_eq!(report.refined.len(), 4);
    let w = report.winner().expect("generous SLO yields a winner");
    assert!(w.outcome.slo_ok, "the winner's SLO verdict must be pass");
    assert!(w.outcome.completed > 0);
    assert!(w.analytic_tok_w > 0.0);
    // The winner is the best *measured* SLO-passing cell.
    for c in report.refined.iter().filter(|c| c.outcome.slo_ok) {
        assert!(w.outcome.tok_per_watt >= c.outcome.tok_per_watt);
    }
}

#[test]
fn stage_b_never_returns_an_slo_violating_winner() {
    let t = azure_conversations();
    let report = optimize(&t, &quick_cfg(1e-12), 2);
    assert!(!report.refined.is_empty());
    assert!(
        report.refined.iter().all(|c| !c.outcome.slo_ok),
        "a 1 ps TTFT SLO is unmeetable"
    );
    assert!(report.winner().is_none());
}

/// The K=2 reduction oracle: the partition-native optimizer restricted
/// to two-entry cutoff vectors must reproduce the PR 3 two-pool
/// `Topology::FleetOpt` path **bit-identically** through both stages —
/// the same Eq. 4 floats in stage A, the same simulated outcome in
/// stage B.
#[test]
fn k2_partition_reduction_replays_the_fleetopt_two_pool_path_bitwise() {
    let t = azure_conversations();
    let cfg = quick_cfg(1e3);
    let report = optimize(&t, &cfg, 2);

    // Stage A: every screened K=2 cell carries the FleetOpt bits.
    assert!(!report.screened.is_empty());
    for c in &report.screened {
        assert_eq!(c.cutoffs, vec![c.b_short(), LONG_CTX]);
        let fleetopt = analyze_cell(
            &Topology::FleetOpt {
                b_short: c.b_short(),
                short_ctx: c.b_short().max(1024),
                gamma: c.gamma,
            },
            &t,
            cfg.gen.lambda_rps,
            h100(),
            cfg.lbar,
            cfg.rho,
            cfg.slo.ttft_p99_s,
            cfg.acct,
            ModelAxis::Dense,
        );
        assert_eq!(
            c.analytic.tok_per_watt.0.to_bits(),
            fleetopt.tok_per_watt.0.to_bits(),
            "stage A drifted from the two-pool FleetOpt closed form at \
             B_short={} γ={}",
            c.b_short(),
            c.gamma
        );
        assert_eq!(c.analytic.total_groups, fleetopt.total_groups);
    }

    // Stage B: each refined cell replays a hand-built FleetOpt spec
    // bit-for-bit — same routed fleet, same trace, same engine path.
    for c in &report.refined {
        let spec = ScenarioSpec::new(
            Topology::FleetOpt {
                b_short: c.b_short(),
                short_ctx: c.b_short().max(1024),
                gamma: c.gamma,
            },
            c.gpu,
            t.clone(),
            cfg.gen.clone(),
        )
        .with_groups(cfg.groups)
        .with_dispatch(&c.dispatch)
        .with_slo(cfg.slo)
        .with_lbar(cfg.lbar)
        .with_rho(cfg.rho);
        let out = spec.simulate_trace(&spec.trace(), false);
        assert_eq!(
            c.outcome.tok_per_watt.to_bits(),
            out.tok_per_watt.to_bits(),
            "stage B drifted from the two-pool FleetOpt fleet at \
             B_short={} γ={} dispatch={}",
            c.b_short(),
            c.gamma,
            c.dispatch
        );
        assert_eq!(c.outcome.joules.to_bits(), out.joules.to_bits());
        assert_eq!(c.outcome.output_tokens, out.output_tokens);
        assert_eq!(
            c.outcome.p99_ttft_s.to_bits(),
            out.p99_ttft_s.to_bits()
        );
    }
}

/// The homogeneous-reduction oracle, through BOTH optimizer stages: a
/// search whose only GPU cells are explicit all-H100 per-pool overrides
/// must reproduce the legacy homogeneous H100 search bit-for-bit — the
/// same Eq. 4 floats in stage A (override-resolved profiles vs the
/// fleet-default profile) and the same simulated outcomes in stage B.
/// This is the drift pin the heterogeneity refactor hangs on.
#[test]
fn homogeneous_override_search_replays_the_legacy_search_bitwise() {
    let t = azure_conversations();
    let partitions = vec![vec![4096, LONG_CTX], vec![2048, 8192, LONG_CTX]];
    let base = OptimizeConfig {
        partitions: partitions.clone(),
        gammas: vec![1.0, 2.0],
        dispatches: vec!["rr".into(), "jsq".into()],
        gen: GenConfig {
            lambda_rps: 120.0,
            duration_s: 0.4,
            max_prompt_tokens: 20_000,
            max_output_tokens: 64,
            seed: 23,
        },
        groups: 3,
        slo: SloTargets { ttft_p99_s: 1e3 },
        top_k: 3,
        ..Default::default()
    };
    let legacy = OptimizeConfig { gpus: vec![Gpu::H100], ..base.clone() };
    let overridden = OptimizeConfig {
        gpus: Vec::new(),
        gpu_axis: GpuAxis::Explicit(vec![
            vec![Gpu::H100, Gpu::H100],
            vec![Gpu::H100, Gpu::H100, Gpu::H100],
        ]),
        ..base
    };

    let a = optimize(&t, &legacy, 2);
    let b = optimize(&t, &overridden, 2);
    assert_eq!(a.screened.len(), b.screened.len());
    for (x, y) in a.screened.iter().zip(&b.screened) {
        assert_eq!(x.cutoffs, y.cutoffs);
        assert_eq!(x.gamma, y.gamma);
        assert_eq!(x.gpus, y.gpus, "both resolve to all-H100 vectors");
        assert_eq!(
            x.analytic.tok_per_watt.0.to_bits(),
            y.analytic.tok_per_watt.0.to_bits(),
            "stage A drifted at cutoffs {:?} γ {}",
            x.cutoffs,
            x.gamma
        );
        assert_eq!(x.analytic.total_groups, y.analytic.total_groups);
    }
    assert_eq!(a.refined.len(), b.refined.len());
    for (x, y) in a.refined.iter().zip(&b.refined) {
        assert_eq!(x.cutoffs, y.cutoffs);
        assert_eq!(x.dispatch, y.dispatch);
        assert_eq!(
            x.outcome.tok_per_watt.to_bits(),
            y.outcome.tok_per_watt.to_bits(),
            "stage B drifted at cutoffs {:?} dispatch {}",
            x.cutoffs,
            x.dispatch
        );
        assert_eq!(x.outcome.joules.to_bits(), y.outcome.joules.to_bits());
        assert_eq!(
            x.outcome.p99_ttft_s.to_bits(),
            y.outcome.p99_ttft_s.to_bits()
        );
    }
}

/// The acceptance claim: with heterogeneous assignments enabled, the
/// optimizer finds a mixed H100/B200 fleet whose *measured* tok/W
/// strictly beats the homogeneous-H100 winner (on long-prompt-heavy
/// traffic, where the upgraded long pools dominate the energy bill).
#[test]
fn mixed_fleet_measured_tok_w_beats_the_homogeneous_h100_winner() {
    let t = agent_heavy();
    let cfg = OptimizeConfig {
        gpus: vec![Gpu::H100, Gpu::B200],
        partitions: vec![vec![4096, 16384, LONG_CTX]],
        gpu_axis: GpuAxis::Mixed,
        gammas: vec![1.0],
        dispatches: vec!["rr".into()],
        gen: GenConfig {
            lambda_rps: 150.0,
            duration_s: 1.0,
            max_prompt_tokens: 60_000,
            max_output_tokens: 128,
            seed: 17,
        },
        groups: 6,
        slo: SloTargets { ttft_p99_s: 1e3 },
        // 2 homogeneous + 6 mixed cells: refine the whole screen.
        top_k: 8,
        ..Default::default()
    };
    let report = optimize(&t, &cfg, 2);
    assert_eq!(report.screened.len(), 8, "2 homogeneous + 2^3 - 2 mixed");
    assert_eq!(report.refined.len(), 8);
    let measured = |mixed: bool| {
        report
            .refined
            .iter()
            .filter(|c| {
                let is_mixed = c.gpus.windows(2).any(|w| w[0] != w[1]);
                is_mixed == mixed
                    && (mixed || c.gpus.iter().all(|g| *g == Gpu::H100))
            })
            .map(|c| c.outcome.tok_per_watt)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let best_mixed = measured(true);
    let homo_h100 = measured(false);
    assert!(best_mixed.is_finite() && homo_h100.is_finite());
    assert!(
        best_mixed > homo_h100,
        "best mixed fleet {best_mixed} must strictly beat the \
         homogeneous-H100 winner {homo_h100} (measured tok/W)"
    );
    // The generous SLO yields a winner, and the report labels mixed
    // cells by their per-pool assignment.
    assert!(report.winner().is_some());
    assert!(report.rowset().to_csv().contains('|'));
}

/// The branch-and-bound oracle: on every K ∈ 2..=3 ladder grid the B&B
/// mixed screen must reproduce the brute-force cross-product ranking
/// **bit for bit** — same cells, same order, same Eq. 4 floats — for a
/// 2-generation and a 3-generation set. With an uncapped keep budget no
/// subtree may be pruned at all (the bound only ever cuts against a
/// full kept set), so the two enumerations are exactly the same work
/// re-ordered.
#[test]
fn bnb_screen_replays_the_brute_force_cross_product_bitwise_on_k_le_3() {
    let t = azure_conversations();
    let mut partitions = kpool_partitions(2);
    partitions.extend(kpool_partitions(3));
    let cases: [(&[Gpu], &[f64]); 2] = [
        (&[Gpu::H100, Gpu::B200], &[1.0, 2.0]),
        (&[Gpu::H100, Gpu::H200, Gpu::B200], &[1.0]),
    ];
    for (gpus, gammas) in cases {
        let run = |mode, keep| {
            screen_mixed(
                &t,
                400.0,
                &partitions,
                gpus,
                gammas,
                LBarPolicy::Window,
                0.85,
                1e3,
                PowerAccounting::PerGpu,
                mode,
                keep,
                ModelAxis::Dense,
            )
        };
        let (brute, bstats) = run(MixedScreen::BruteForce, usize::MAX);
        let (bnb, nstats) = run(MixedScreen::BranchAndBound, usize::MAX);
        assert_eq!(bstats.brute_cells as usize, brute.len());
        assert_eq!(nstats.pruned, 0, "uncapped keep ⇒ nothing may prune");
        assert_eq!(nstats.full_evals, bstats.brute_cells);
        assert_eq!(brute.len(), bnb.len());
        for (a, b) in brute.iter().zip(&bnb) {
            assert_eq!(a.cutoffs, b.cutoffs);
            assert_eq!(a.gpus, b.gpus, "ranking order must match bitwise");
            assert_eq!(a.gamma.to_bits(), b.gamma.to_bits());
            assert_eq!(
                a.report.tok_per_watt.0.to_bits(),
                b.report.tok_per_watt.0.to_bits(),
                "B&B drifted from brute force at cutoffs {:?} γ {} {:?}",
                a.cutoffs,
                a.gamma,
                a.gpus
            );
            assert_eq!(a.report.total_groups, b.report.total_groups);
        }
    }
}

/// Under the default keep budget the truncated B&B ranking is a bitwise
/// prefix of the brute-force ranking — in particular the stage-A mixed
/// winner is identical — even when the K ≤ 3 grid is far wider than the
/// beam.
#[test]
fn bnb_default_keep_preserves_the_brute_force_winner_and_prefix() {
    let t = agent_heavy();
    let mut partitions = kpool_partitions(2);
    partitions.extend(kpool_partitions(3));
    let gpus = [Gpu::H100, Gpu::B200];
    let gammas = [1.0, 2.0];
    let run = |mode, keep| {
        screen_mixed(
            &t,
            400.0,
            &partitions,
            &gpus,
            &gammas,
            LBarPolicy::Window,
            0.85,
            1e3,
            PowerAccounting::PerGpu,
            mode,
            keep,
            ModelAxis::Dense,
        )
    };
    let (brute, bstats) = run(MixedScreen::BruteForce, usize::MAX);
    let keep = OptimizeConfig::default().mixed_keep;
    let (bnb, nstats) = run(MixedScreen::BranchAndBound, keep);
    assert!(
        bstats.brute_cells as usize > keep,
        "the grid must overflow the beam for this oracle to bite"
    );
    assert_eq!(bnb.len(), keep);
    assert!(nstats.full_evals == keep as u64);
    for (a, b) in brute.iter().zip(&bnb) {
        assert_eq!(a.cutoffs, b.cutoffs);
        assert_eq!(a.gpus, b.gpus);
        assert_eq!(a.gamma.to_bits(), b.gamma.to_bits());
        assert_eq!(
            a.report.tok_per_watt.0.to_bits(),
            b.report.tok_per_watt.0.to_bits()
        );
    }
}

/// The scale the cross-product could not reach: a K=5 partition with a
/// 3-generation set (3⁵ − 3 = 240 mixed cells) screens through B&B with
/// a tight beam, returns exactly the brute ranking's head, best-first.
#[test]
fn bnb_opens_k5_three_generation_screens_and_matches_brute_head() {
    let t = agent_heavy();
    let partitions = vec![vec![2048, 8192, 16384, 32768, LONG_CTX]];
    let gpus = [Gpu::H100, Gpu::H200, Gpu::B200];
    let gammas = [1.0];
    let run = |mode, keep| {
        screen_mixed(
            &t,
            400.0,
            &partitions,
            &gpus,
            &gammas,
            LBarPolicy::Window,
            0.85,
            1e3,
            PowerAccounting::PerGpu,
            mode,
            keep,
            ModelAxis::Dense,
        )
    };
    let (brute, bstats) = run(MixedScreen::BruteForce, usize::MAX);
    assert_eq!(bstats.brute_cells, 3u64.pow(5) - 3);
    let (bnb, nstats) = run(MixedScreen::BranchAndBound, 8);
    assert_eq!(bnb.len(), 8);
    assert_eq!(nstats.full_evals, 8, "only the beam re-enters Eq. 4");
    for w in bnb.windows(2) {
        assert!(
            w[0].report.tok_per_watt.0 >= w[1].report.tok_per_watt.0,
            "B&B survivors must come back best-first"
        );
    }
    for (a, b) in brute.iter().zip(&bnb) {
        assert_eq!(a.gpus, b.gpus);
        assert_eq!(
            a.report.tok_per_watt.0.to_bits(),
            b.report.tok_per_watt.0.to_bits()
        );
    }
}

/// The greedy budgeted-upgrade axis: with an effectively unlimited
/// budget the path walks to the all-B200 fleet, strictly improving at
/// every step and never exceeding the budget; with a zero-ish budget
/// no upgrade fits and only the homogeneous floor is screened.
#[test]
fn budget_axis_walks_a_monotone_upgrade_path_within_budget() {
    let t = agent_heavy();
    let mk = |max_groups: u32| OptimizeConfig {
        gpus: vec![Gpu::H100],
        partitions: vec![vec![4096, 16384, LONG_CTX]],
        gpu_axis: GpuAxis::Budget(UpgradeBudget {
            to: Gpu::B200,
            max_groups,
        }),
        gammas: vec![1.0],
        dispatches: vec!["rr".into()],
        top_k: 1,
        ..Default::default()
    };
    let wide = screen(&t, &mk(u32::MAX));
    // 1 homogeneous floor + one cell per greedy step (at most K = 3
    // steps; each screened step contains B200 pools).
    assert!(
        (2..=4).contains(&wide.len()),
        "floor plus 1..=3 greedy steps, got {}",
        wide.len()
    );
    let mut steps: Vec<&wattlaw::scenario::optimize::ScreenedCell> = wide
        .iter()
        .filter(|c| c.gpus.iter().any(|g| *g == Gpu::B200))
        .collect();
    assert!(!steps.is_empty(), "an unlimited budget upgrades something");
    steps.sort_by_key(|c| {
        c.gpus.iter().filter(|g| **g == Gpu::B200).count()
    });
    let floor = wide
        .iter()
        .find(|c| c.gpus.iter().all(|g| *g == Gpu::H100))
        .expect("homogeneous floor screened");
    let mut prev = floor.analytic.tok_per_watt.0;
    for c in steps {
        assert!(
            c.analytic.tok_per_watt.0 > prev,
            "greedy step must strictly improve: {:?}",
            c.gpus
        );
        prev = c.analytic.tok_per_watt.0;
    }
    // A zero budget admits no upgrade: only the floor remains.
    let tight = screen(&t, &mk(0));
    assert_eq!(tight.len(), 1);
    assert!(tight[0].gpus.iter().all(|g| *g == Gpu::H100));
}

/// The legacy §10.3 closed form and the K-pool `analyze()` path must
/// agree to 1e-12 on the legacy grids (the legacy entry point is now a
/// wrapper over `Topology::Partition` — this pins the reduction).
#[test]
fn legacy_multi_pool_agrees_with_kpool_analyze_to_1e12() {
    let grids: [&[u32]; 3] = [
        &[8192, LONG_CTX],
        &[4096, 16384, LONG_CTX],
        &[2048, 8192, 32768, LONG_CTX],
    ];
    for trace in [azure_conversations(), agent_heavy()] {
        for windows in grids {
            let legacy = multi_pool(
                &trace,
                1000.0,
                h100(),
                windows,
                LBarPolicy::Window,
                0.85,
                0.5,
                PowerAccounting::PerGpu,
            );
            let partition = analyze_cell(
                &Topology::partition(windows),
                &trace,
                1000.0,
                h100(),
                LBarPolicy::Window,
                0.85,
                0.5,
                PowerAccounting::PerGpu,
                ModelAxis::Dense,
            );
            assert!(
                (legacy.tok_per_watt.0 - partition.tok_per_watt.0).abs()
                    <= 1e-12,
                "{}: {windows:?}: legacy {} vs partition {}",
                trace.name,
                legacy.tok_per_watt.0,
                partition.tok_per_watt.0
            );
            assert_eq!(legacy.total_groups, partition.total_groups);
            assert_eq!(legacy.pools.len(), partition.pools.len());
        }
    }
}

/// Shared base config for the K-grid monotonicity/consistency oracles:
/// γ fixed to 1 so partitioning is the only lever, TrafficMean L̄ so the
/// closed form models the live-L̄ roofline the simulator actually runs,
/// and a generous SLO so throughput (not the TTFT tail) sizes pools.
/// Outputs are capped at the partition pools' 1024-token headroom (so
/// no request is ever rejected) and the duration is long relative to a
/// request's holding time (so ramp-up/drain edges stay small against
/// the steady state the closed form describes).
fn kgrid_cfg() -> OptimizeConfig {
    OptimizeConfig {
        gpus: vec![Gpu::H100],
        gammas: vec![1.0],
        dispatches: vec!["rr".into()],
        gen: GenConfig {
            lambda_rps: 400.0,
            duration_s: 120.0,
            // prompt + output fits every pool: interior windows carry
            // 1024 tokens of headroom above their cutoff, and
            // 61440 + 1024 ≤ the 64K long window.
            max_prompt_tokens: 61_440,
            max_output_tokens: 1024,
            seed: 13,
        },
        lbar: LBarPolicy::TrafficMean,
        slo: SloTargets { ttft_p99_s: 1e3 },
        top_k: 1,
        ..Default::default()
    }
}

fn best_partition(t: &WorkloadTrace, k: u32) -> wattlaw::scenario::optimize::ScreenedCell {
    let cfg = OptimizeConfig { partitions: kpool_partitions(k), ..kgrid_cfg() };
    screen(t, &cfg).swap_remove(0)
}

/// Finer partitions keep harvesting the 1/W law: on the mixed-length
/// agent-heavy workload the K=3 analytical winner must be at least as
/// good as the K=2 winner — and strictly better on at least one of the
/// three workload sweep cells.
#[test]
fn k3_analytical_winner_is_at_least_the_k2_winner_on_mixed_traffic() {
    let agent = agent_heavy();
    let k2 = best_partition(&agent, 2);
    let k3 = best_partition(&agent, 3);
    assert!(
        k3.analytic.tok_per_watt.0 >= k2.analytic.tok_per_watt.0,
        "K=3 winner {} ({:?}) below K=2 winner {} ({:?})",
        k3.analytic.tok_per_watt.0,
        k3.cutoffs,
        k2.analytic.tok_per_watt.0,
        k2.cutoffs
    );

    let mut strictly_better = 0;
    for t in [azure_conversations(), lmsys_chat(), agent] {
        let k2 = best_partition(&t, 2);
        let k3 = best_partition(&t, 3);
        if k3.analytic.tok_per_watt.0 > k2.analytic.tok_per_watt.0 {
            strictly_better += 1;
        }
    }
    assert!(
        strictly_better >= 1,
        "K=3 never strictly beat K=2 on any workload sweep cell"
    );
}

/// Stage-B consistency for the K=3 winner: replay it through the event
/// engine with the fleet sized exactly as the analytical plan says
/// (per-pool group overrides), and the measured tok/W must land within
/// ±15 % of the stage-A analytical value.
#[test]
fn k3_winner_simulated_tok_w_within_15pct_of_analytical() {
    use wattlaw::fleet::topology::PartitionPool;
    use wattlaw::scenario::rel_delta_pct;
    use wattlaw::workload::synth::generate;

    let cfg = kgrid_cfg();
    // The closed form's L̄_out is the workload's mean output length; the
    // generated trace caps outputs at the pools' 1024-token headroom.
    // Compare like with like: measure the capped trace's empirical mean
    // and hand the closed form a workload carrying exactly that demand
    // — the delta then measures model fidelity, not the output cap.
    let t = agent_heavy();
    let trace = generate(&t, &cfg.gen);
    let mean_out = trace.iter().map(|r| r.output_tokens as f64).sum::<f64>()
        / trace.len() as f64;
    let t_capped = WorkloadTrace { mean_output_tokens: mean_out, ..t };

    let k3 = {
        let c = OptimizeConfig {
            partitions: kpool_partitions(3),
            ..cfg.clone()
        };
        screen(&t_capped, &c).swap_remove(0)
    };

    // The analytical plan's fleet, pool for pool.
    let pools: Vec<PartitionPool> = k3
        .cutoffs
        .iter()
        .zip(&k3.analytic.pools)
        .map(|(&cutoff, p)| {
            assert!(p.sizing.groups > 0, "every tier carries traffic");
            PartitionPool {
                cutoff,
                gpu: None,
                groups: Some(p.sizing.groups as u32),
            }
        })
        .collect();
    let total_groups: u32 = pools.iter().map(|p| p.groups.unwrap()).sum();
    let spec = ScenarioSpec::new(
        Topology::Partition { pools, gamma: 1.0 },
        Gpu::H100,
        t_capped,
        cfg.gen.clone(),
    )
    .with_groups(total_groups)
    .with_dispatch("rr")
    .with_slo(cfg.slo)
    .with_lbar(cfg.lbar);

    let sim = spec.simulate_trace(&trace, true);
    assert_eq!(sim.completed as usize, trace.len(), "no rejections");
    assert!(sim.warnings.is_empty(), "every pool carries traffic");
    let delta = rel_delta_pct(sim.tok_per_watt, k3.analytic.tok_per_watt.0);
    assert!(
        delta.abs() <= 15.0,
        "K=3 winner {:?} ({} groups): simulated {} vs analytical {} tok/W \
         (delta {delta:+.1}% exceeds ±15%)",
        k3.cutoffs,
        total_groups,
        sim.tok_per_watt,
        k3.analytic.tok_per_watt.0
    );
}

#[test]
fn optimize_json_pairs_stage_a_and_stage_b_per_refined_cell() {
    let t = azure_conversations();
    let report = optimize(&t, &quick_cfg(1e3), 2);
    let doc = wattlaw::runtime::json::parse(&report.rowset().to_json())
        .expect("optimizer emits valid JSON");
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), report.refined.len());
    for r in rows {
        assert!(
            r.get("analyze tok/W").unwrap().as_f64().is_some(),
            "stage-A number missing"
        );
        assert!(
            r.get("simulate tok/W").unwrap().as_f64().is_some(),
            "stage-B number missing"
        );
    }
    assert_eq!(rows[0].get("slo").unwrap().as_str(), Some("pass"));
    assert_eq!(rows[0].get("winner").unwrap().as_str(), Some("*"));
}
