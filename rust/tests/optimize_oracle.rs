//! Regression oracle for the scenario-native optimizer: restricted to
//! the legacy `B_SHORT_GRID × GAMMA_GRID`, stage A must rank the same
//! best (B_short, γ) cell as the old closed-form `sweep_fleetopt` — and
//! stage B must never crown an SLO-violating winner.

use std::sync::Arc;

use wattlaw::fleet::optimizer::{optimize_fleetopt, sweep_fleetopt};
use wattlaw::fleet::pool::LBarPolicy;
use wattlaw::fleet::profile::{GpuProfile, ManualProfile, PowerAccounting};
use wattlaw::power::Gpu;
use wattlaw::scenario::optimize::{optimize, screen, OptimizeConfig};
use wattlaw::scenario::SloTargets;
use wattlaw::workload::cdf::azure_conversations;
use wattlaw::workload::synth::GenConfig;

fn h100() -> Arc<dyn GpuProfile> {
    Arc::new(ManualProfile::h100_70b())
}

/// The new stage-A screen, restricted to the legacy grid (the
/// `OptimizeConfig` default axes ARE the legacy grids), must agree with
/// the legacy closed-form sweep cell for cell — same winner, same
/// tok/W bits.
#[test]
fn stage_a_matches_legacy_sweep_on_the_legacy_grid() {
    let t = azure_conversations();
    let legacy = sweep_fleetopt(
        &t,
        1000.0,
        h100(),
        LBarPolicy::Window,
        0.85,
        0.5,
        PowerAccounting::PerGpu,
    );
    let cfg = OptimizeConfig { gpus: vec![Gpu::H100], ..Default::default() };
    let screened = screen(&t, &cfg);
    assert_eq!(screened.len(), legacy.len());
    // Same best cell, bit-identical analytical tok/W down the ranking.
    for (s, l) in screened.iter().zip(&legacy) {
        assert_eq!(s.b_short, l.b_short);
        assert_eq!(s.gamma, l.gamma);
        assert_eq!(
            s.analytic.tok_per_watt.0.to_bits(),
            l.report.tok_per_watt.0.to_bits()
        );
    }
}

#[test]
fn legacy_wrapper_still_finds_the_same_optimum() {
    // `optimize_fleetopt` (the old public API) now routes through the
    // scenario optimizer's screen; its contract is unchanged.
    let t = azure_conversations();
    let best = optimize_fleetopt(
        &t,
        1000.0,
        h100(),
        LBarPolicy::Window,
        0.85,
        0.5,
        PowerAccounting::PerGpu,
    );
    assert!(best.gamma > 1.0, "γ* = {}", best.gamma);
    let all = sweep_fleetopt(
        &t,
        1000.0,
        h100(),
        LBarPolicy::Window,
        0.85,
        0.5,
        PowerAccounting::PerGpu,
    );
    for r in &all {
        assert!(best.report.tok_per_watt.0 >= r.report.tok_per_watt.0);
    }
}

fn quick_cfg(slo_s: f64) -> OptimizeConfig {
    OptimizeConfig {
        gpus: vec![Gpu::H100],
        b_shorts: vec![2048, 4096],
        gammas: vec![1.0, 2.0],
        dispatches: vec!["rr".into(), "jsq".into()],
        gen: GenConfig {
            lambda_rps: 150.0,
            duration_s: 0.4,
            max_prompt_tokens: 20_000,
            max_output_tokens: 64,
            seed: 11,
        },
        groups: 2,
        slo: SloTargets { ttft_p99_s: slo_s },
        top_k: 2,
        ..Default::default()
    }
}

#[test]
fn stage_b_winner_is_measured_and_slo_clean() {
    let t = azure_conversations();
    let report = optimize(&t, &quick_cfg(1e3), 2);
    // top_k cells × 2 dispatch policies, each carrying both engines.
    assert_eq!(report.refined.len(), 4);
    let w = report.winner().expect("generous SLO yields a winner");
    assert!(w.outcome.slo_ok, "the winner's SLO verdict must be pass");
    assert!(w.outcome.completed > 0);
    assert!(w.analytic_tok_w > 0.0);
    // The winner is the best *measured* SLO-passing cell.
    for c in report.refined.iter().filter(|c| c.outcome.slo_ok) {
        assert!(w.outcome.tok_per_watt >= c.outcome.tok_per_watt);
    }
}

#[test]
fn stage_b_never_returns_an_slo_violating_winner() {
    let t = azure_conversations();
    let report = optimize(&t, &quick_cfg(1e-12), 2);
    assert!(!report.refined.is_empty());
    assert!(
        report.refined.iter().all(|c| !c.outcome.slo_ok),
        "a 1 ps TTFT SLO is unmeetable"
    );
    assert!(report.winner().is_none());
}

#[test]
fn optimize_json_pairs_stage_a_and_stage_b_per_refined_cell() {
    let t = azure_conversations();
    let report = optimize(&t, &quick_cfg(1e3), 2);
    let doc = wattlaw::runtime::json::parse(&report.rowset().to_json())
        .expect("optimizer emits valid JSON");
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), report.refined.len());
    for r in rows {
        assert!(
            r.get("analyze tok/W").unwrap().as_f64().is_some(),
            "stage-A number missing"
        );
        assert!(
            r.get("simulate tok/W").unwrap().as_f64().is_some(),
            "stage-B number missing"
        );
    }
    assert_eq!(rows[0].get("slo").unwrap().as_str(), Some("pass"));
    assert_eq!(rows[0].get("winner").unwrap().as_str(), Some("*"));
}
