//! Property-based suites (via the in-crate `xcheck` mini-framework):
//! invariants of the power model, KV geometry, roofline, queueing,
//! workload CDFs, routing, paged allocation and continuous batching under
//! randomized inputs.

use wattlaw::fleet::profile::{
    GpuProfile, ManualProfile, ModelAxis, PowerAccounting,
};
use wattlaw::model::spec::{CATALOG, LLAMA31_70B};
use wattlaw::model::{kappa_bytes_per_token, n_max, KvPlacement};
use wattlaw::power::{Gpu, LogisticPower};
use wattlaw::queueing::erlang;
use wattlaw::roofline::Roofline;
use wattlaw::router::context::ContextRouter;
use wattlaw::router::fleetopt::FleetOptRouter;
use wattlaw::router::Router;
use wattlaw::serve::batcher::{Batcher, SlotWork};
use wattlaw::serve::kvblocks::BlockAllocator;
use wattlaw::serve::request::ServeRequest;
use wattlaw::tokeconomy::operating_point;
use wattlaw::workload::cdf::{agent_heavy, azure_conversations, lmsys_chat};
use wattlaw::workload::Request;
use wattlaw::xcheck::forall;
use wattlaw::xcheck_assert;

#[test]
fn prop_power_monotone_and_bounded() {
    forall("P(b) monotone, in [idle, nom]", 300, |g| {
        let gpu = *g.choose(&Gpu::ALL);
        let p = gpu.spec().power;
        let b1 = g.f64_in(0.0, 2000.0);
        let b2 = g.f64_in(0.0, 2000.0);
        let (lo, hi) = if b1 <= b2 { (b1, b2) } else { (b2, b1) };
        let w_lo = p.power_w(lo);
        let w_hi = p.power_w(hi);
        xcheck_assert!(w_lo <= w_hi + 1e-9, "P({lo})={w_lo} > P({hi})={w_hi}");
        xcheck_assert!(w_lo >= p.p_idle_w - 1e-9 && w_hi <= p.p_nom_w + 1e-9);
        Ok(())
    });
}

#[test]
fn prop_nmax_scaling_eq3() {
    forall("n_max inversely proportional to context (within floor)", 300, |g| {
        let v_kv = g.f64_in(1e9, 2e11);
        let model = CATALOG[g.usize_in(0, CATALOG.len() - 1)];
        let kappa = kappa_bytes_per_token(model, KvPlacement::Sharded, 8);
        let ctx = g.pow2(10, 16);
        let n1 = n_max(v_kv, kappa, ctx);
        let n2 = n_max(v_kv, kappa, ctx * 2);
        // Doubling context at least halves (floor can only shrink n2).
        xcheck_assert!(
            n2 <= n1 / 2 + 1,
            "n_max({ctx})={n1}, n_max({})={n2}",
            ctx * 2
        );
        // And never to zero.
        xcheck_assert!(n2 >= 1);
        Ok(())
    });
}

#[test]
fn prop_roofline_monotonicity() {
    forall("τ increasing in n and L̄; throughput increasing in n", 300, |g| {
        let r = Roofline::manual(g.f64_in(1.0, 10.0), g.f64_in(0.01, 0.5));
        let n = g.f64_in(1.0, 512.0);
        let l = g.f64_in(128.0, 131_072.0);
        xcheck_assert!(r.tau_ms(n + 1.0, l) > r.tau_ms(n, l));
        xcheck_assert!(r.tau_ms(n, l * 1.5) > r.tau_ms(n, l));
        // More concurrency still yields more total throughput (τ is
        // affine in n with positive intercept).
        xcheck_assert!(
            r.throughput_tok_s(n + 1.0, l) > r.throughput_tok_s(n, l)
        );
        Ok(())
    });
}

#[test]
fn prop_tok_per_watt_decreasing_in_context() {
    forall("Eq. 2 tok/W strictly decreasing in window", 100, |g| {
        let p = ManualProfile::h100_70b();
        let c1 = g.pow2(11, 16);
        let c2 = c1 * 2;
        let t1 = operating_point(&p, c1, 1.0, PowerAccounting::PerGpu)
            .tok_per_watt
            .0;
        let t2 = operating_point(&p, c2, 1.0, PowerAccounting::PerGpu)
            .tok_per_watt
            .0;
        xcheck_assert!(t2 < t1, "tok/W({c2})={t2} !< tok/W({c1})={t1}");
        Ok(())
    });
}

#[test]
fn prop_context_halving_law_holds_on_every_model_axis() {
    // The paper's 1/W slope, per architecture: with n_max ∝ 1/L and
    // L̄ = L, the product n·L̄ — hence τ — is context-invariant, so
    // doubling the window must halve analytical tok/W up to the n_max
    // floor and the (mild) power-curve slope. Weight streaming and
    // speculative decode rescale W and H0 but keep the same functional
    // form, so the slope must survive on all three model axes.
    forall("tok/W(2L)/tok/W(L) ≈ 1/2 per model axis", 120, |g| {
        let gpu = *g.choose(&Gpu::ALL);
        let model = *g.choose(&[
            ModelAxis::Dense,
            ModelAxis::MoeStreaming { dispatch_ms: 0.0 },
            ModelAxis::Speculative {
                k: ModelAxis::SPEC_K,
                alpha: ModelAxis::SPEC_ALPHA,
            },
        ]);
        let p = model.profile_for(gpu);
        let ctx = g.pow2(12, 15); // 4K..32K so the doubled window ≤ 64K
        let tpw = |c: u32| {
            operating_point(&p, c, 1.0, PowerAccounting::PerGpu)
                .tok_per_watt
                .0
        };
        let ratio = tpw(ctx * 2) / tpw(ctx);
        xcheck_assert!(
            (0.45..=0.65).contains(&ratio),
            "{} {}: tok/W(2·{ctx})/tok/W({ctx}) = {ratio}",
            model.label(),
            p.label()
        );
        Ok(())
    });
}

#[test]
fn prop_erlang_c_bounds_and_monotonicity() {
    forall("Erlang-C in [0,1], decreasing in c, increasing in a", 300, |g| {
        let c = g.u64_in(1, 500);
        let a = g.f64_in(0.1, c as f64 * 0.99);
        let pc = erlang::erlang_c(c, a);
        xcheck_assert!((0.0..=1.0).contains(&pc), "C({c},{a})={pc}");
        let pc_more_servers = erlang::erlang_c(c + 1, a);
        xcheck_assert!(pc_more_servers <= pc + 1e-12);
        let pc_more_load = erlang::erlang_c(c, (a * 1.01).min(c as f64 * 0.999));
        xcheck_assert!(pc_more_load >= pc - 1e-12);
        Ok(())
    });
}

#[test]
fn prop_cdf_quantile_inverse() {
    forall("CDF/quantile inverse pair; monotone", 200, |g| {
        let trace = match g.usize_in(0, 2) {
            0 => azure_conversations(),
            1 => lmsys_chat(),
            _ => agent_heavy(),
        };
        let p = g.f64_in(0.01, 0.99);
        let x = trace.prompt_cdf.quantile(p);
        let back = trace.prompt_cdf.frac_leq(x);
        xcheck_assert!((back - p).abs() < 1e-6, "p={p} x={x} back={back}");
        let p2 = g.f64_in(0.01, 0.99);
        let (lo, hi) = if p <= p2 { (p, p2) } else { (p2, p) };
        xcheck_assert!(
            trace.prompt_cdf.quantile(lo) <= trace.prompt_cdf.quantile(hi) + 1e-9
        );
        Ok(())
    });
}

#[test]
fn prop_routing_total_and_deterministic() {
    forall("routers are total, stable, pool-bounded", 300, |g| {
        let b_short = g.pow2(9, 14);
        let gamma = g.f64_in(1.0, 4.0);
        let req = Request {
            id: g.u64_in(0, u64::MAX / 2),
            arrival_s: 0.0,
            prompt_tokens: g.u64_in(1, 131_072) as u32,
            output_tokens: g.u64_in(1, 4096) as u32,
        };
        for router in [
            Box::new(ContextRouter::two_pool(b_short)) as Box<dyn Router>,
            Box::new(FleetOptRouter::new(b_short, gamma)),
        ] {
            let r1 = router.route(&req);
            let r2 = router.route(&req);
            xcheck_assert!(r1 == r2, "non-deterministic {}", router.name());
            xcheck_assert!(r1.pool < router.num_pools());
            xcheck_assert!(r1.effective_prompt_tokens >= 1);
            xcheck_assert!(r1.effective_prompt_tokens <= req.prompt_tokens);
        }
        Ok(())
    });
}

#[test]
fn prop_block_allocator_conservation() {
    forall("blocks conserved across admit/grow/release", 150, |g| {
        let blocks = g.u64_in(8, 512) as u32;
        let mut a = BlockAllocator::new(64, blocks);
        let n_ops = g.usize_in(1, 60);
        let mut live: Vec<u64> = Vec::new();
        for op in 0..n_ops {
            match g.usize_in(0, 2) {
                0 => {
                    let id = op as u64;
                    if a.admit(id, g.u64_in(1, 2048) as u32) {
                        live.push(id);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let idx = g.usize_in(0, live.len() - 1);
                        a.grow(live[idx], g.u64_in(1, 4096) as u32);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = g.usize_in(0, live.len() - 1);
                        a.release(live.swap_remove(idx));
                    }
                }
            }
            xcheck_assert!(a.used() <= blocks, "overcommit");
        }
        for id in live {
            a.release(id);
        }
        xcheck_assert!(a.used() == 0, "leak: {} blocks", a.used());
        Ok(())
    });
}

#[test]
fn prop_batcher_serves_everything_exactly_once() {
    forall("batcher completes each request once, frees all memory", 60, |g| {
        let slots = g.usize_in(1, 12);
        let blocks = g.u64_in(64, 1024) as u32;
        let window = 4096u32;
        let mut b = Batcher::new(slots, BlockAllocator::new(64, blocks), 128, window);
        let n_reqs = g.usize_in(1, 40);
        let mut submitted = 0u64;
        for i in 0..n_reqs {
            let prompt = g.u64_in(1, 2048) as u32;
            let output = g.u64_in(1, 256) as u32;
            let ok = b.submit(ServeRequest {
                id: i as u64,
                prompt_tokens: prompt,
                output_tokens: output,
                arrival_s: 0.0,
            });
            // Requests that fit the window must be accepted.
            xcheck_assert!(ok == (prompt + output <= window));
            if ok {
                submitted += 1;
            }
        }
        let mut completed = std::collections::HashSet::new();
        let mut t = 0.0;
        let mut guard = 0;
        while b.has_work() {
            b.admit(t);
            t += 1.0;
            let plan = b.plan();
            let active = plan.iter().any(|w| !matches!(w, SlotWork::Idle));
            xcheck_assert!(active, "wedged with queued work");
            for (i, w) in plan.into_iter().enumerate() {
                if !matches!(w, SlotWork::Idle) {
                    if let Some(c) = b.on_step(i, w, t) {
                        xcheck_assert!(
                            completed.insert(c.id),
                            "duplicate completion {}",
                            c.id
                        );
                    }
                }
            }
            guard += 1;
            xcheck_assert!(guard < 500_000, "runaway");
        }
        xcheck_assert!(
            completed.len() as u64 == submitted,
            "{} of {} completed",
            completed.len(),
            submitted
        );
        xcheck_assert!(b.blocks.used() == 0, "KV leak");
        Ok(())
    });
}

#[test]
fn prop_logistic_fit_recovers_random_truths() {
    forall("fit recovers randomly parameterized logistics", 40, |g| {
        let truth = LogisticPower::new(
            g.f64_in(100.0, 600.0),
            g.f64_in(700.0, 1300.0),
            g.f64_in(0.6, 2.0),
            g.f64_in(2.0, 8.0),
        );
        let samples: Vec<_> = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0,
                               256.0, 512.0, 1024.0]
            .iter()
            .map(|&b| wattlaw::power::mlenergy::PowerSample {
                batch: b,
                watts: truth.power_w(b),
            })
            .collect();
        let fit = wattlaw::power::fit::fit_logistic(&samples);
        xcheck_assert!(
            fit.max_rel_err < 0.02,
            "fit err {} for truth {truth:?}",
            fit.max_rel_err
        );
        Ok(())
    });
}

#[test]
fn prop_fleet_profiles_consistent() {
    forall("profile n_max halves per doubling; power per-group = tp × per-gpu",
           120, |g| {
        let gpu = *g.choose(&Gpu::ALL);
        let p = ManualProfile::for_gpu(gpu);
        let ctx = g.pow2(11, 16);
        let n1 = p.n_max(ctx);
        let n2 = p.n_max(ctx * 2);
        xcheck_assert!(n2 <= n1 / 2 + 1 && n2 >= 1);
        let b = g.f64_in(0.0, 512.0);
        let per_gpu = p.group_power_w(b, PowerAccounting::PerGpu);
        let per_group = p.group_power_w(b, PowerAccounting::PerGroup);
        xcheck_assert!((per_group / per_gpu - p.tp() as f64).abs() < 1e-9);
        Ok(())
    });
}

#[test]
fn prop_quant_never_hurts_dense_throughput() {
    use wattlaw::model::spec::Precision;
    forall("fp8 ≥ fp16 throughput for dense models at any point", 100, |g| {
        let gpu = *g.choose(&Gpu::ALL);
        let n = g.f64_in(1.0, 256.0);
        let l = g.f64_in(512.0, 65_536.0);
        let f16 = Roofline::from_specs(
            gpu.spec(), &LLAMA31_70B, Precision::Fp16, 8, KvPlacement::Sharded);
        let f8 = Roofline::from_specs(
            gpu.spec(), &LLAMA31_70B, Precision::Fp8, 8, KvPlacement::Sharded);
        xcheck_assert!(
            f8.throughput_tok_s(n, l) >= f16.throughput_tok_s(n, l)
        );
        Ok(())
    });
}

#[test]
fn prop_carbon_metrics_linear_in_intensity() {
    use wattlaw::fleet::carbon::{carbon_report, GridContext};
    use wattlaw::fleet::analysis::fleet_tpw_analysis;
    use wattlaw::fleet::pool::LBarPolicy;
    use wattlaw::fleet::topology::{Topology, LONG_CTX};
    use std::sync::Arc;
    let pools = Topology::Homogeneous { ctx: LONG_CTX }.pools(
        &azure_conversations(), 1000.0,
        Arc::new(ManualProfile::h100_70b()), None,
        LBarPolicy::Window, 0.85, 0.5);
    let fleet = fleet_tpw_analysis(&pools, PowerAccounting::PerGpu);
    forall("gCO2/token linear in grid intensity; $/Mtok in price", 100, |g| {
        let base = GridContext {
            pue: g.f64_in(1.0, 2.0),
            carbon_g_per_kwh: g.f64_in(10.0, 1000.0),
            price_per_kwh: g.f64_in(0.01, 0.5),
        };
        let k = g.f64_in(1.1, 5.0);
        let scaled = GridContext {
            carbon_g_per_kwh: base.carbon_g_per_kwh * k,
            price_per_kwh: base.price_per_kwh * k,
            ..base
        };
        let a = carbon_report(&fleet, &base);
        let b = carbon_report(&fleet, &scaled);
        xcheck_assert!(
            (b.g_co2_per_token / a.g_co2_per_token - k).abs() < 1e-9,
            "carbon not linear"
        );
        xcheck_assert!(
            (b.usd_per_mtok / a.usd_per_mtok - k).abs() < 1e-9,
            "cost not linear"
        );
        Ok(())
    });
}

#[test]
fn prop_speculative_bounds() {
    use wattlaw::roofline::speculative::{spec_point, SpecConfig};
    let r = Roofline::manual(6.72, 0.1387);
    let p = LogisticPower::h100();
    forall("speculative point is physically bounded", 200, |g| {
        let cfg = SpecConfig {
            k: g.u64_in(1, 8) as u32,
            alpha: g.f64_in(0.0, 0.99),
            draft_w_ms: g.f64_in(0.01, 1.0),
            draft_power_scale: g.f64_in(0.5, 1.0),
        };
        let n = g.f64_in(1.0, 128.0);
        let s = spec_point(&r, &p, &cfg, n, 8192.0);
        // Expected tokens per iter in [1, k+1].
        xcheck_assert!(
            s.expected_tokens_per_iter >= 1.0
                && s.expected_tokens_per_iter <= (cfg.k + 1) as f64 + 1e-12,
            "E[tok] = {}",
            s.expected_tokens_per_iter
        );
        // Power within the logistic envelope.
        xcheck_assert!(
            s.power_w >= p.p_idle_w * cfg.draft_power_scale.min(1.0) - 1e-9
                && s.power_w <= p.p_nom_w + 1e-9,
            "P = {}",
            s.power_w
        );
        xcheck_assert!(s.tok_per_watt.is_finite() && s.tok_per_watt > 0.0);
        Ok(())
    });
}

#[test]
fn prop_adaptive_controller_stays_on_grid() {
    use wattlaw::fleet::adaptive::{AdaptiveSplit, BOUNDS};
    forall("adaptive boundary always on the planner grid", 50, |g| {
        let mut ctl = AdaptiveSplit::new(4096, 512);
        let n = g.usize_in(100, 3000);
        for _ in 0..n {
            let p = g.u64_in(1, 131_072) as u32;
            let b = ctl.observe(p);
            xcheck_assert!(
                BOUNDS.contains(&b) || b == 4096,
                "boundary {b} off grid"
            );
        }
        Ok(())
    });
}

/// Random small sim scenario shared by the event-engine properties:
/// windows are multiples of 64 with output headroom, so every generated
/// request fits its pool and must complete exactly once.
fn random_sim_scenario(
    g: &mut wattlaw::xcheck::Gen,
) -> (Vec<wattlaw::workload::Request>, Vec<u32>, Vec<wattlaw::sim::GroupSimConfig>) {
    use wattlaw::fleet::profile::GpuProfile;
    use wattlaw::sim::GroupSimConfig;
    use wattlaw::workload::synth::{generate, GenConfig};

    let p = ManualProfile::h100_70b();
    let mk = |window: u32, n_max: u32| GroupSimConfig {
        window_tokens: window,
        n_max,
        roofline: p.roofline(),
        power: p.gpu().power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    };
    let two_pools = g.bool();
    // Prompts beyond the 4096 split go to the 64K pool, so any length is
    // window-safe in the two-pool scenario; the single 8K pool needs
    // prompt + output ≤ window.
    let trace = generate(
        &azure_conversations(),
        &GenConfig {
            lambda_rps: g.f64_in(10.0, 60.0),
            duration_s: g.f64_in(0.5, 2.0),
            max_prompt_tokens: if two_pools { 20_000 } else { 7_000 },
            max_output_tokens: 256,
            seed: g.u64_in(0, 1 << 40),
        },
    );
    let (groups, cfgs) = if two_pools {
        (
            vec![g.u64_in(1, 3) as u32, g.u64_in(1, 2) as u32],
            vec![
                mk(4096 + 1024, g.u64_in(4, 32) as u32),
                mk(65_536, g.u64_in(4, 16) as u32),
            ],
        )
    } else {
        (
            vec![g.u64_in(1, 4) as u32],
            vec![mk(8192, g.u64_in(4, 64) as u32)],
        )
    };
    (trace, groups, cfgs)
}

#[test]
fn prop_kpool_partition_covers_range_and_conserves_tokens() {
    use std::sync::Arc;
    use wattlaw::fleet::pool::LBarPolicy;
    use wattlaw::fleet::topology::Topology;
    use wattlaw::sim::dispatch::RoundRobin;
    use wattlaw::sim::simulate_topology_with;
    use wattlaw::workload::synth::{generate, GenConfig};

    forall("K-pool partition: full cover, no overlap, conservation", 6, |g| {
        // Random K ∈ {2,3,4} with random strictly increasing interior
        // cutoffs off the ladder; the long pool always serves to 64K.
        let ladder = [2048u32, 4096, 8192, 16384, 32768];
        let k = g.usize_in(2, 4);
        let mut cuts = Vec::new();
        let mut lo = 0usize;
        for j in 0..(k - 1) {
            let remaining = (k - 1) - j - 1;
            let hi = ladder.len() - 1 - remaining;
            let pick = g.usize_in(lo, hi);
            cuts.push(ladder[pick]);
            lo = pick + 1;
        }
        cuts.push(65_536);
        let topo = Topology::partition(&cuts);

        // (a) Analytical cover: the pool λ slices tile the workload —
        // nothing dropped, nothing double-counted.
        let profile: Arc<dyn GpuProfile> = Arc::new(ManualProfile::h100_70b());
        let pools = topo.pools(
            &azure_conversations(),
            1000.0,
            profile,
            None,
            LBarPolicy::Window,
            0.85,
            0.5,
        );
        xcheck_assert!(pools.len() == k);
        let sum: f64 = pools.iter().map(|p| p.inputs.lambda_rps).sum();
        xcheck_assert!((sum - 1000.0).abs() < 1e-6, "λ tiles: {sum}");

        // (b) Router totality and no overlap: every prompt length maps
        // to exactly the bucket its cutoffs select.
        let router = topo.router();
        for _ in 0..64 {
            let p = g.u64_in(1, 100_000) as u32;
            let req = Request {
                id: 0,
                arrival_s: 0.0,
                prompt_tokens: p,
                output_tokens: 1,
            };
            let route = router.route(&req);
            xcheck_assert!(route.pool < k, "pool {} of {k}", route.pool);
            if route.pool > 0 {
                xcheck_assert!(
                    p > cuts[route.pool - 1],
                    "p={p} below its pool's lower cutoff"
                );
            }
            if route.pool + 1 < k {
                xcheck_assert!(
                    p <= cuts[route.pool],
                    "p={p} above cutoff {}",
                    cuts[route.pool]
                );
            }
        }

        // (c) Simulated conservation: per-pool output tokens sum to the
        // trace total (every request fits its pool's window, so nothing
        // is rejected either).
        let trace = generate(
            &azure_conversations(),
            &GenConfig {
                lambda_rps: g.f64_in(10.0, 40.0),
                duration_s: g.f64_in(0.5, 1.5),
                max_prompt_tokens: 60_000,
                max_output_tokens: 256,
                seed: g.u64_in(0, 1 << 40),
            },
        );
        let p2 = ManualProfile::h100_70b();
        let total_groups = k as u32 + g.u64_in(0, 3) as u32;
        let (pool_groups, cfgs) = topo.sim_pools(&p2, total_groups, 1024);
        let mut rr = RoundRobin::new();
        let r = simulate_topology_with(
            &trace,
            router.as_ref(),
            &pool_groups,
            &cfgs,
            &mut rr,
            g.bool(),
        );
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        xcheck_assert!(
            r.output_tokens == want,
            "fleet tokens {} of {want}",
            r.output_tokens
        );
        let per_pool: u64 = r.pools.iter().map(|p| p.output_tokens).sum();
        xcheck_assert!(per_pool == want, "per-pool sum {per_pool} of {want}");
        let done: u64 = r.pools.iter().map(|p| p.metrics.completed).sum();
        xcheck_assert!(done == trace.len() as u64);
        let rejected: u64 = r.pools.iter().map(|p| p.metrics.rejected).sum();
        xcheck_assert!(rejected == 0, "{rejected} rejected");
        Ok(())
    });
}

#[test]
fn prop_mixed_fleet_analyze_is_the_poolwise_eq4_sum() {
    use std::sync::Arc;
    use wattlaw::fleet::pool::LBarPolicy;
    use wattlaw::fleet::topology::Topology;
    use wattlaw::scenario::optimize::analyze_cell;

    // Under a random per-pool GPU assignment, each pool's Eq. 4 line
    // depends only on its own generation: pool i of the mixed fleet
    // must be bit-identical to pool i of the homogeneous fleet that
    // serves every pool on gpus[i], and the fleet figure must be the
    // pool-wise sum — heterogeneity composes, it does not couple.
    forall("mixed-fleet analyze == pool-wise Eq. 4 sum", 12, |g| {
        let ladder = [2048u32, 4096, 8192, 16384, 32768];
        let k = g.usize_in(2, 4);
        let mut cuts = Vec::new();
        let mut lo = 0usize;
        for j in 0..(k - 1) {
            let remaining = (k - 1) - j - 1;
            let hi = ladder.len() - 1 - remaining;
            let pick = g.usize_in(lo, hi);
            cuts.push(ladder[pick]);
            lo = pick + 1;
        }
        cuts.push(65_536);
        let gpus: Vec<Gpu> = (0..k).map(|_| *g.choose(&Gpu::ALL)).collect();
        let analyze = |topo: &Topology| {
            analyze_cell(
                topo,
                &azure_conversations(),
                1000.0,
                Arc::new(ManualProfile::h100_70b()),
                LBarPolicy::Window,
                0.85,
                0.5,
                PowerAccounting::PerGpu,
                ModelAxis::Dense,
            )
        };
        let mixed =
            analyze(&Topology::partition_with_gpus(&cuts, &gpus, 1.0));
        xcheck_assert!(mixed.pools.len() == k);
        let (mut power_sum, mut demand_sum) = (0.0f64, 0.0f64);
        for (i, &gpu) in gpus.iter().enumerate() {
            let homo = analyze(&Topology::partition_with_gpus(
                &cuts,
                &vec![gpu; k],
                1.0,
            ));
            let (a, b) = (&mixed.pools[i], &homo.pools[i]);
            xcheck_assert!(
                a.power.0.to_bits() == b.power.0.to_bits(),
                "pool {i} power depends on more than its own GPU: \
                 {} vs {}",
                a.power.0,
                b.power.0
            );
            xcheck_assert!(
                a.demand_tok_s.to_bits() == b.demand_tok_s.to_bits()
            );
            xcheck_assert!(a.sizing.groups == b.sizing.groups);
            xcheck_assert!(
                a.tok_per_watt.0.to_bits() == b.tok_per_watt.0.to_bits()
            );
            power_sum += a.power.0;
            demand_sum += a.demand_tok_s;
        }
        // Fleet figure = Σ demand / Σ power over the same pool lines.
        xcheck_assert!(
            (mixed.tok_per_watt.0 - demand_sum / power_sum).abs() <= 1e-12,
            "fleet tok/W {} vs pool-wise {}",
            mixed.tok_per_watt.0,
            demand_sum / power_sum
        );
        xcheck_assert!(
            (mixed.total_power.0 - power_sum).abs() <= 1e-9 * power_sum
        );
        Ok(())
    });
}

#[test]
fn prop_event_sim_conserves_tokens_and_replays_across_policies() {
    use wattlaw::router::context::ContextRouter;
    use wattlaw::sim::{dispatch, simulate_topology_with};

    forall("event sim: conservation + determinism, any policy", 10, |g| {
        let (trace, groups, cfgs) = random_sim_scenario(g);
        let router: Box<dyn Router> = if groups.len() == 2 {
            Box::new(ContextRouter::two_pool(4096))
        } else {
            Box::new(wattlaw::router::HomogeneousRouter)
        };
        let policy_name = *g.choose(&dispatch::ALL);
        let (par_a, par_b) = (g.bool(), g.bool());
        let run = |parallel: bool| {
            let mut policy = dispatch::parse(policy_name).unwrap();
            simulate_topology_with(
                &trace,
                router.as_ref(),
                &groups,
                &cfgs,
                policy.as_mut(),
                parallel,
            )
        };
        let a = run(par_a);
        let b = run(par_b);

        // Token conservation: every request fits its pool's window, so
        // everything completes and output tokens are conserved.
        let want: u64 = trace.iter().map(|r| r.output_tokens as u64).sum();
        xcheck_assert!(
            a.output_tokens == want,
            "{policy_name}: {} of {} output tokens",
            a.output_tokens,
            want
        );
        let done: u64 = a.pools.iter().map(|p| p.metrics.completed).sum();
        xcheck_assert!(
            done == trace.len() as u64,
            "{policy_name}: {done} of {} completed",
            trace.len()
        );
        let rejected: u64 = a.pools.iter().map(|p| p.metrics.rejected).sum();
        xcheck_assert!(rejected == 0, "{policy_name}: {rejected} rejected");

        // Determinism: bit-identical replay, including energy.
        xcheck_assert!(a.output_tokens == b.output_tokens);
        xcheck_assert!(
            a.joules.to_bits() == b.joules.to_bits(),
            "{policy_name}: joules replay {} vs {}",
            a.joules,
            b.joules
        );
        xcheck_assert!(a.steps == b.steps);
        Ok(())
    });
}

#[test]
fn prop_event_sim_parallel_matches_sequential_bitwise() {
    use wattlaw::router::context::ContextRouter;
    use wattlaw::sim::dispatch::RoundRobin;
    use wattlaw::sim::simulate_topology_with;

    forall("event sim: parallel == sequential, bit for bit", 8, |g| {
        let (trace, groups, cfgs) = random_sim_scenario(g);
        let router: Box<dyn Router> = if groups.len() == 2 {
            Box::new(ContextRouter::two_pool(4096))
        } else {
            Box::new(wattlaw::router::HomogeneousRouter)
        };
        let mut rr_a = RoundRobin::new();
        let seq = simulate_topology_with(
            &trace, router.as_ref(), &groups, &cfgs, &mut rr_a, false,
        );
        let mut rr_b = RoundRobin::new();
        let par = simulate_topology_with(
            &trace, router.as_ref(), &groups, &cfgs, &mut rr_b, true,
        );
        xcheck_assert!(seq.output_tokens == par.output_tokens);
        xcheck_assert!(
            seq.joules.to_bits() == par.joules.to_bits(),
            "joules {} vs {}",
            seq.joules,
            par.joules
        );
        xcheck_assert!(seq.steps == par.steps);
        for (s, p) in seq.pools.iter().zip(&par.pools) {
            xcheck_assert!(s.horizon_s.to_bits() == p.horizon_s.to_bits());
            xcheck_assert!(s.mean_batch.to_bits() == p.mean_batch.to_bits());
            xcheck_assert!(s.metrics.completed == p.metrics.completed);
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_fleet_state_equals_fresh_snapshot_on_random_traces() {
    use wattlaw::router::adaptive::AdaptiveRouter;
    use wattlaw::router::context::ContextRouter;
    use wattlaw::sim::{
        dispatch, simulate_topology_opts, EngineOptions, StateMode,
    };

    // Two assertions per case: (1) `validate_state` makes the engine
    // compare its incrementally maintained FleetState against a freshly
    // built snapshot after EVERY event (it panics on the first
    // divergence); (2) the pre-refactor rebuild-per-arrival oracle mode
    // must replay the incremental run bit-for-bit — same decisions, same
    // floats, only the snapshot allocations removed.
    forall("incremental live state == fresh snapshot, any event", 8, |g| {
        let (trace, groups, cfgs) = random_sim_scenario(g);
        let stateful = ["jsq", "least-kv", "power"];
        // Force a load-aware consumer so the state is actually read:
        // a stateful dispatch policy, a load-aware router, or both.
        let (router, policy_name): (Box<dyn Router>, &str) =
            if groups.len() == 2 {
                if g.bool() {
                    (
                        Box::new(
                            AdaptiveRouter::new(4096)
                                .with_spill_factor(g.f64_in(0.5, 4.0)),
                        ),
                        *g.choose(&dispatch::ALL),
                    )
                } else {
                    (
                        Box::new(ContextRouter::two_pool(4096)),
                        *g.choose(&stateful),
                    )
                }
            } else {
                (
                    Box::new(wattlaw::router::HomogeneousRouter),
                    *g.choose(&stateful),
                )
            };
        let run = |mode: StateMode, validate: bool| {
            let mut policy = dispatch::parse(policy_name).unwrap();
            simulate_topology_opts(
                &trace,
                router.as_ref(),
                &groups,
                &cfgs,
                policy.as_mut(),
                EngineOptions {
                    allow_parallel: false,
                    state_mode: mode,
                    validate_state: validate,
                    ..Default::default()
                },
            )
        };
        let live = run(StateMode::Incremental, true);
        let oracle = run(StateMode::RebuildPerArrival, false);
        xcheck_assert!(live.output_tokens == oracle.output_tokens);
        xcheck_assert!(
            live.joules.to_bits() == oracle.joules.to_bits(),
            "{policy_name}: joules diverged, {} vs {}",
            live.joules,
            oracle.joules
        );
        xcheck_assert!(live.steps == oracle.steps);
        for (a, b) in live.pools.iter().zip(&oracle.pools) {
            xcheck_assert!(a.horizon_s.to_bits() == b.horizon_s.to_bits());
            xcheck_assert!(a.metrics.completed == b.metrics.completed);
        }
        Ok(())
    });
}

#[test]
fn prop_calendar_queue_replays_binary_heap_bitwise_across_policies() {
    use wattlaw::router::adaptive::AdaptiveRouter;
    use wattlaw::router::context::ContextRouter;
    use wattlaw::sim::{
        dispatch, simulate_topology_opts, EngineOptions, QueueMode, StateMode,
    };

    // The calendar/bucket queue and the retained binary heap implement
    // the same strict (time, kind, sequence) total order, so entire
    // simulations — decisions, floats, energy — must replay bit-for-bit
    // between [`QueueMode::Calendar`] and the [`QueueMode::BinaryHeap`]
    // oracle, across every dispatch policy, router flavor and StateMode.
    forall("calendar queue == binary-heap oracle, bit for bit", 10, |g| {
        let (trace, groups, cfgs) = random_sim_scenario(g);
        let (router, policy_name): (Box<dyn Router>, &str) =
            if groups.len() == 2 {
                if g.bool() {
                    (
                        Box::new(
                            AdaptiveRouter::new(4096)
                                .with_spill_factor(g.f64_in(0.5, 4.0)),
                        ),
                        *g.choose(&dispatch::ALL),
                    )
                } else {
                    (
                        Box::new(ContextRouter::two_pool(4096)),
                        *g.choose(&dispatch::ALL),
                    )
                }
            } else {
                (
                    Box::new(wattlaw::router::HomogeneousRouter),
                    *g.choose(&dispatch::ALL),
                )
            };
        let state_mode = if g.bool() {
            StateMode::Incremental
        } else {
            StateMode::RebuildPerArrival
        };
        let run = |queue_mode: QueueMode| {
            let mut policy = dispatch::parse(policy_name).unwrap();
            simulate_topology_opts(
                &trace,
                router.as_ref(),
                &groups,
                &cfgs,
                policy.as_mut(),
                EngineOptions {
                    allow_parallel: false,
                    state_mode,
                    queue_mode,
                    ..Default::default()
                },
            )
        };
        let cal = run(QueueMode::Calendar);
        let heap = run(QueueMode::BinaryHeap);
        xcheck_assert!(cal.output_tokens == heap.output_tokens);
        xcheck_assert!(
            cal.joules.to_bits() == heap.joules.to_bits(),
            "{policy_name}/{state_mode:?}: joules diverged, {} vs {}",
            cal.joules,
            heap.joules
        );
        xcheck_assert!(cal.steps == heap.steps);
        for (a, b) in cal.pools.iter().zip(&heap.pools) {
            xcheck_assert!(a.horizon_s.to_bits() == b.horizon_s.to_bits());
            xcheck_assert!(a.mean_batch.to_bits() == b.mean_batch.to_bits());
            xcheck_assert!(a.metrics.completed == b.metrics.completed);
            xcheck_assert!(a.metrics.rejected == b.metrics.rejected);
        }
        Ok(())
    });
}

#[test]
fn prop_streamed_arrivals_replay_materialized_bitwise_across_policies() {
    use wattlaw::router::adaptive::AdaptiveRouter;
    use wattlaw::sim::{
        dispatch, simulate_topology_opts, simulate_topology_source,
        EngineOptions, GroupSimConfig, QueueMode,
    };
    use wattlaw::workload::synth::{generate, GenConfig};
    use wattlaw::workload::SynthSource;

    // The streamed engine pulls arrivals one at a time and numbers
    // step/wake events from 0 instead of trace.len(); the seq-offset
    // argument in `sim::events` says no event comparison can flip, so
    // entire simulations must replay the materialized oracle bit for
    // bit — across every dispatch policy, both queue modes and both
    // router flavors of the random scenario.
    forall("streamed arrivals == materialized oracle, bit for bit", 6, |g| {
        let p = ManualProfile::h100_70b();
        let mk = |window: u32, n_max: u32| GroupSimConfig {
            window_tokens: window,
            n_max,
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        };
        let two_pools = g.bool();
        let workload = azure_conversations();
        let gen = GenConfig {
            lambda_rps: g.f64_in(10.0, 60.0),
            duration_s: g.f64_in(0.5, 2.0),
            max_prompt_tokens: if two_pools { 20_000 } else { 7_000 },
            max_output_tokens: 256,
            seed: g.u64_in(0, 1 << 40),
        };
        let trace = generate(&workload, &gen);
        let (groups, cfgs) = if two_pools {
            (
                vec![g.u64_in(1, 3) as u32, g.u64_in(1, 2) as u32],
                vec![
                    mk(4096 + 1024, g.u64_in(4, 32) as u32),
                    mk(65_536, g.u64_in(4, 16) as u32),
                ],
            )
        } else {
            (
                vec![g.u64_in(1, 4) as u32],
                vec![mk(8192, g.u64_in(4, 64) as u32)],
            )
        };
        let router: Box<dyn Router> = if two_pools {
            if g.bool() {
                Box::new(
                    AdaptiveRouter::new(4096)
                        .with_spill_factor(g.f64_in(0.5, 4.0)),
                )
            } else {
                Box::new(ContextRouter::two_pool(4096))
            }
        } else {
            Box::new(wattlaw::router::HomogeneousRouter)
        };
        for queue_mode in [QueueMode::Calendar, QueueMode::BinaryHeap] {
            for policy_name in dispatch::ALL {
                let opts = EngineOptions {
                    allow_parallel: false,
                    queue_mode,
                    ..Default::default()
                };
                let mut pol = dispatch::parse(policy_name).unwrap();
                let mat = simulate_topology_opts(
                    &trace,
                    router.as_ref(),
                    &groups,
                    &cfgs,
                    pol.as_mut(),
                    opts,
                );
                let mut pol = dispatch::parse(policy_name).unwrap();
                let mut src = SynthSource::new(&workload, &gen);
                let stream = simulate_topology_source(
                    &mut src,
                    router.as_ref(),
                    &groups,
                    &cfgs,
                    pol.as_mut(),
                    opts,
                );
                xcheck_assert!(stream.output_tokens == mat.output_tokens);
                xcheck_assert!(
                    stream.joules.to_bits() == mat.joules.to_bits(),
                    "{policy_name}/{queue_mode:?}: joules diverged, \
                     {} vs {}",
                    stream.joules,
                    mat.joules
                );
                xcheck_assert!(stream.steps == mat.steps);
                xcheck_assert!(
                    stream.idle_joules.to_bits() == mat.idle_joules.to_bits()
                );
                for (a, b) in stream.pools.iter().zip(&mat.pools) {
                    xcheck_assert!(
                        a.horizon_s.to_bits() == b.horizon_s.to_bits()
                    );
                    xcheck_assert!(
                        a.mean_batch.to_bits() == b.mean_batch.to_bits()
                    );
                    xcheck_assert!(a.metrics.completed == b.metrics.completed);
                    xcheck_assert!(a.metrics.rejected == b.metrics.rejected);
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_stream_replays_sequential_bitwise() {
    use wattlaw::sim::{
        dispatch, simulate_topology_opts, simulate_topology_source,
        DispatchPolicy, EngineOptions, GroupSimConfig, QueueMode, StepMode,
    };
    use wattlaw::workload::synth::GenConfig;
    use wattlaw::workload::SynthSource;

    // The sharded streaming fast path demuxes arrivals to one worker
    // thread per group over bounded channels. Per `sim::events`: each
    // group's sub-simulation is exactly the pre-assigned split the
    // materialized parallel path runs, and the streamed feed replays
    // the materialized feed bitwise — so all three engines (sequential
    // streamed, sharded streamed, materialized parallel) must agree bit
    // for bit, across every dispatch policy, both queue modes and both
    // step modes. Load-aware policies are not arrival-static; for them
    // `allow_parallel` falls back to the sequential engine, which makes
    // the identity trivially strict there too.
    forall("sharded stream == sequential stream, bit for bit", 4, |g| {
        let p = ManualProfile::h100_70b();
        let mk = |window: u32, n_max: u32| GroupSimConfig {
            window_tokens: window,
            n_max,
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        };
        let workload = azure_conversations();
        let gen = GenConfig {
            lambda_rps: g.f64_in(10.0, 60.0),
            duration_s: g.f64_in(0.5, 2.0),
            max_prompt_tokens: 20_000,
            max_output_tokens: 256,
            seed: g.u64_in(0, 1 << 40),
        };
        // Always more than one group in total, so eligibility turns on
        // the dispatch policy alone.
        let groups =
            vec![g.u64_in(1, 3) as u32, g.u64_in(1, 2) as u32 + 1];
        let cfgs = vec![
            mk(4096 + 1024, g.u64_in(4, 32) as u32),
            mk(65_536, g.u64_in(4, 16) as u32),
        ];
        let router = ContextRouter::two_pool(4096);
        let trace =
            wattlaw::workload::synth::generate(&workload, &gen);
        for queue_mode in [QueueMode::Calendar, QueueMode::BinaryHeap] {
            for step_mode in [StepMode::Fused, StepMode::PerStep] {
                for policy_name in dispatch::ALL {
                    let seq_opts = EngineOptions {
                        allow_parallel: false,
                        queue_mode,
                        step_mode,
                        ..Default::default()
                    };
                    let par_opts =
                        EngineOptions { allow_parallel: true, ..seq_opts };
                    let mut pol = dispatch::parse(policy_name).unwrap();
                    let eligible = pol.is_arrival_static();
                    let mut src = SynthSource::new(&workload, &gen);
                    let seq = simulate_topology_source(
                        &mut src, &router, &groups, &cfgs, pol.as_mut(),
                        seq_opts,
                    );
                    let mut pol = dispatch::parse(policy_name).unwrap();
                    let mut src = SynthSource::new(&workload, &gen);
                    let sharded = simulate_topology_source(
                        &mut src, &router, &groups, &cfgs, pol.as_mut(),
                        par_opts,
                    );
                    let mut pol = dispatch::parse(policy_name).unwrap();
                    let mat = simulate_topology_opts(
                        &trace, &router, &groups, &cfgs, pol.as_mut(),
                        par_opts,
                    );
                    for (name, run) in [("sharded", &sharded), ("mat", &mat)]
                    {
                        xcheck_assert!(
                            run.output_tokens == seq.output_tokens
                        );
                        xcheck_assert!(
                            run.joules.to_bits() == seq.joules.to_bits(),
                            "{policy_name}/{queue_mode:?}/{step_mode:?} \
                             {name}: joules diverged, {} vs {}",
                            run.joules,
                            seq.joules
                        );
                        xcheck_assert!(run.steps == seq.steps);
                        xcheck_assert!(
                            run.idle_joules.to_bits()
                                == seq.idle_joules.to_bits()
                        );
                        for (a, b) in run.pools.iter().zip(&seq.pools) {
                            xcheck_assert!(
                                a.horizon_s.to_bits() == b.horizon_s.to_bits()
                            );
                            xcheck_assert!(
                                a.mean_batch.to_bits()
                                    == b.mean_batch.to_bits()
                            );
                            xcheck_assert!(
                                a.metrics.completed == b.metrics.completed
                            );
                            xcheck_assert!(
                                a.metrics.rejected == b.metrics.rejected
                            );
                        }
                    }
                    // Event counts: the sharded demux pops exactly the
                    // per-group totals of the materialized parallel
                    // split. The sequential shared queue fuses past
                    // other groups' arrivals only under Fused mode, so
                    // per-step counts match it exactly and fused counts
                    // can only shrink.
                    xcheck_assert!(
                        sharded.events_popped == mat.events_popped,
                        "{policy_name}/{queue_mode:?}/{step_mode:?}: \
                         sharded popped {} vs materialized {}",
                        sharded.events_popped,
                        mat.events_popped
                    );
                    if !eligible || step_mode == StepMode::PerStep {
                        xcheck_assert!(
                            sharded.events_popped == seq.events_popped
                        );
                    } else {
                        xcheck_assert!(
                            sharded.events_popped <= seq.events_popped
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fused_macro_steps_replay_per_step_bitwise_across_policies() {
    use wattlaw::router::adaptive::AdaptiveRouter;
    use wattlaw::sim::{
        dispatch, simulate_topology_opts, simulate_topology_source,
        EngineOptions, GroupSimConfig, QueueMode, StepMode,
    };
    use wattlaw::workload::synth::{generate, GenConfig};
    use wattlaw::workload::SynthSource;

    // Macro-stepping fuses every decode/ingest step that provably ends
    // before the next arrival into one in-line loop. The loop makes the
    // same τ(n, L̄)/meter/batcher calls in the same order as the
    // one-event-per-step schedule, so every float must replay the
    // [`StepMode::PerStep`] oracle bit for bit — across all five
    // dispatch policies × both queue modes × streamed and materialized
    // arrivals — while popping strictly fewer events.
    forall("fused macro-steps == per-step oracle, bit for bit", 4, |g| {
        let p = ManualProfile::h100_70b();
        let mk = |window: u32, n_max: u32| GroupSimConfig {
            window_tokens: window,
            n_max,
            roofline: p.roofline(),
            power: p.gpu().power,
            gpus_charged: 1.0,
            ingest_chunk: 1024,
        };
        let two_pools = g.bool();
        let workload = azure_conversations();
        let gen = GenConfig {
            lambda_rps: g.f64_in(10.0, 60.0),
            duration_s: g.f64_in(0.5, 2.0),
            max_prompt_tokens: if two_pools { 20_000 } else { 7_000 },
            max_output_tokens: 256,
            seed: g.u64_in(0, 1 << 40),
        };
        let trace = generate(&workload, &gen);
        let (groups, cfgs) = if two_pools {
            (
                vec![g.u64_in(1, 3) as u32, g.u64_in(1, 2) as u32],
                vec![
                    mk(4096 + 1024, g.u64_in(4, 32) as u32),
                    mk(65_536, g.u64_in(4, 16) as u32),
                ],
            )
        } else {
            (
                vec![g.u64_in(1, 4) as u32],
                vec![mk(8192, g.u64_in(4, 64) as u32)],
            )
        };
        let router: Box<dyn Router> = if two_pools {
            if g.bool() {
                Box::new(
                    AdaptiveRouter::new(4096)
                        .with_spill_factor(g.f64_in(0.5, 4.0)),
                )
            } else {
                Box::new(ContextRouter::two_pool(4096))
            }
        } else {
            Box::new(wattlaw::router::HomogeneousRouter)
        };
        for queue_mode in [QueueMode::Calendar, QueueMode::BinaryHeap] {
            for policy_name in dispatch::ALL {
                let opts = |step_mode: StepMode| EngineOptions {
                    allow_parallel: false,
                    queue_mode,
                    step_mode,
                    ..Default::default()
                };
                let run_mat = |step_mode: StepMode| {
                    let mut pol = dispatch::parse(policy_name).unwrap();
                    simulate_topology_opts(
                        &trace,
                        router.as_ref(),
                        &groups,
                        &cfgs,
                        pol.as_mut(),
                        opts(step_mode),
                    )
                };
                let oracle = run_mat(StepMode::PerStep);
                let fused = run_mat(StepMode::Fused);
                let mut pol = dispatch::parse(policy_name).unwrap();
                let mut src = SynthSource::new(&workload, &gen);
                let fused_stream = simulate_topology_source(
                    &mut src,
                    router.as_ref(),
                    &groups,
                    &cfgs,
                    pol.as_mut(),
                    opts(StepMode::Fused),
                );
                // The point of the whole exercise: fewer events, same
                // floats. (Equality only when nothing fused at all,
                // which these multi-step traces never hit.)
                xcheck_assert!(
                    fused.events_popped < oracle.events_popped,
                    "{policy_name}/{queue_mode:?}: fused popped {} vs \
                     per-step {}",
                    fused.events_popped,
                    oracle.events_popped
                );
                xcheck_assert!(
                    fused_stream.events_popped == fused.events_popped
                );
                for (name, run) in
                    [("fused", &fused), ("fused+stream", &fused_stream)]
                {
                    xcheck_assert!(
                        run.output_tokens == oracle.output_tokens
                    );
                    xcheck_assert!(
                        run.joules.to_bits() == oracle.joules.to_bits(),
                        "{policy_name}/{queue_mode:?}/{name}: joules \
                         diverged, {} vs {}",
                        run.joules,
                        oracle.joules
                    );
                    xcheck_assert!(run.steps == oracle.steps);
                    xcheck_assert!(
                        run.idle_joules.to_bits()
                            == oracle.idle_joules.to_bits()
                    );
                    for (a, b) in run.pools.iter().zip(&oracle.pools) {
                        xcheck_assert!(
                            a.horizon_s.to_bits() == b.horizon_s.to_bits()
                        );
                        xcheck_assert!(
                            a.mean_batch.to_bits() == b.mean_batch.to_bits()
                        );
                        xcheck_assert!(
                            a.metrics.completed == b.metrics.completed
                        );
                        xcheck_assert!(
                            a.metrics.rejected == b.metrics.rejected
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_adaptive_router_live_is_total_and_window_safe() {
    use wattlaw::router::adaptive::AdaptiveRouter;
    use wattlaw::sim::{FleetState, GroupLoad, PoolLoad};

    forall("adaptive route_live: total, in-range, length-safe", 200, |g| {
        let b_short = g.pow2(10, 14);
        let r = AdaptiveRouter::new(b_short)
            .with_spill_factor(g.f64_in(0.5, 4.0));
        let mk_pool = |g: &mut wattlaw::xcheck::Gen, window: u32, n_max: u32| {
            let n = g.usize_in(1, 4);
            PoolLoad {
                window_tokens: window,
                n_max,
                groups: (0..n)
                    .map(|_| GroupLoad {
                        queued: g.usize_in(0, 50),
                        active: g.usize_in(0, 16),
                        free_blocks: g.u64_in(0, 4096) as u32,
                        used_blocks: g.u64_in(0, 4096) as u32,
                    })
                    .collect(),
            }
        };
        let state = FleetState::from_pools(vec![
            mk_pool(g, b_short + 1024, 64),
            mk_pool(g, 65_536, 16),
        ]);
        let req = Request {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: g.u64_in(1, 100_000) as u32,
            output_tokens: g.u64_in(1, 1024) as u32,
        };
        let route = r.route_live(&req, &state);
        xcheck_assert!(route.pool < 2);
        xcheck_assert!(route.effective_prompt_tokens == req.prompt_tokens);
        // A long prompt may never land in the short pool.
        if req.prompt_tokens > b_short {
            xcheck_assert!(route.pool == 1, "long prompt routed short");
        }
        // Decisions are pure in (request, snapshot).
        xcheck_assert!(r.route_live(&req, &state) == route);
        Ok(())
    });
}

#[test]
fn prop_disagg_total_never_exceeds_decode_only() {
    use wattlaw::fleet::disagg::disaggregate;
    use wattlaw::fleet::pool::LBarPolicy;
    use wattlaw::fleet::topology::Topology;
    use std::sync::Arc;
    forall("prefill power only ever lowers tok/W", 20, |g| {
        let b_short = g.pow2(11, 14);
        let r = disaggregate(
            &azure_conversations(),
            g.f64_in(100.0, 2000.0),
            Arc::new(ManualProfile::h100_70b()),
            &Topology::FleetOpt {
                b_short,
                short_ctx: b_short,
                gamma: g.f64_in(1.0, 4.0),
            },
            LBarPolicy::Window,
            0.85,
            0.5,
            PowerAccounting::PerGpu,
        );
        xcheck_assert!(r.tok_per_watt_total <= r.tok_per_watt_decode_only);
        xcheck_assert!(r.prefill_groups >= 1);
        Ok(())
    });
}
