//! Results-layer format tests: golden CSV/JSON emitter output (stable
//! column order, units in headers, NaN/missing-cell policy) plus a
//! property test that `RowSet::to_csv` round-trips through the parser
//! for random tables.

use wattlaw::results::csv::parse_csv;
use wattlaw::results::{Cell, Column, RowSet, Value};
use wattlaw::runtime::json::{parse as parse_json, Json};
use wattlaw::xrand::Rng;

fn golden_rowset() -> RowSet {
    let mut rs = RowSet::new(
        "Golden — scenario cell",
        vec![
            Column::str("Topology"),
            Column::float("analyze tok/W").with_unit("tok/J"),
            Column::float("simulate tok/W").with_unit("tok/J"),
            Column::float("p99 TTFT").with_unit("s"),
            Column::int("completed"),
            Column::str("slo"),
        ],
    );
    rs.push(vec![
        Cell::str("FleetOpt (4K/γ=2)"),
        Cell::float(3.5).shown("3.50"),
        Cell::float(3.25),
        Cell::float(0.125),
        Cell::int(941),
        Cell::str("pass"),
    ]);
    rs.push(vec![
        Cell::str("Homo 64K, with \"quotes\", and, commas"),
        Cell::float(1.5),
        // Nothing completed: the measured side is NaN / missing.
        Cell::float(f64::NAN),
        Cell::missing(),
        Cell::int(0),
        Cell::str("MISS"),
    ]);
    rs.note("golden fixture");
    rs
}

#[test]
fn csv_golden_stable_columns_units_and_nan_policy() {
    assert_eq!(
        golden_rowset().to_csv(),
        "Topology,analyze tok/W (tok/J),simulate tok/W (tok/J),\
         p99 TTFT (s),completed,slo\n\
         FleetOpt (4K/γ=2),3.5,3.25,0.125,941,pass\n\
         \"Homo 64K, with \"\"quotes\"\", and, commas\",1.5,,,0,MISS\n"
    );
}

#[test]
fn json_golden_schema_rows_and_null_policy() {
    let doc = parse_json(&golden_rowset().to_json()).expect("valid JSON");
    assert_eq!(doc.get("title").unwrap().as_str(), Some("Golden — scenario cell"));
    let cols = doc.get("columns").unwrap().as_arr().unwrap();
    assert_eq!(cols.len(), 6);
    assert_eq!(cols[1].get("name").unwrap().as_str(), Some("analyze tok/W"));
    assert_eq!(cols[1].get("unit").unwrap().as_str(), Some("tok/J"));
    assert_eq!(cols[0].get("unit"), Some(&Json::Null));
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    // Display override never leaks: raw value in JSON.
    assert_eq!(rows[0].get("analyze tok/W").unwrap().as_f64(), Some(3.5));
    assert_eq!(rows[1].get("simulate tok/W"), Some(&Json::Null));
    assert_eq!(rows[1].get("p99 TTFT"), Some(&Json::Null));
    assert_eq!(
        doc.get("notes").unwrap().as_arr().unwrap()[0].as_str(),
        Some("golden fixture")
    );
}

/// Golden schema for the new t8 K-pool rowset: stable column order and
/// units in both machine formats (values are simulation-derived, so the
/// schema — not the numbers — is the golden surface).
#[test]
fn t8_kpool_rowset_schema_and_units_are_stable() {
    let rs = wattlaw::tables::t8::rowset();
    let csv = rs.to_csv();
    assert!(
        csv.starts_with(
            "K,topology,analyze tok/W (tok/J),simulate tok/W (tok/J),\
             delta (%),p99 TTFT (s),completed\n"
        ),
        "t8 CSV header drifted:\n{}",
        csv.lines().next().unwrap_or("")
    );
    assert_eq!(csv.lines().count(), 1 + 4, "one row per K in 1..=4");

    let doc = parse_json(&rs.to_json()).expect("t8 emits valid JSON");
    let cols = doc.get("columns").unwrap().as_arr().unwrap();
    assert_eq!(cols.len(), 7);
    assert_eq!(cols[2].get("name").unwrap().as_str(), Some("analyze tok/W"));
    assert_eq!(cols[2].get("unit").unwrap().as_str(), Some("tok/J"));
    assert_eq!(cols[5].get("unit").unwrap().as_str(), Some("s"));
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 4);
    for (i, r) in rows.iter().enumerate() {
        assert_eq!(r.get("K").unwrap().as_f64(), Some((i + 1) as f64));
        assert!(r.get("analyze tok/W").unwrap().as_f64().is_some());
        assert!(r.get("simulate tok/W").unwrap().as_f64().is_some());
    }
}

/// Golden schema for the t9 heterogeneous-fleet rowset: stable column
/// order and units through every emitter, the floor row's missing
/// marginal cell rendered per the NaN/missing policy, and the CSV
/// round-tripping through the crate's own parser (values are
/// simulation-derived, so the schema — not the numbers — is the golden
/// surface).
#[test]
fn t9_hetero_rowset_schema_golden_and_csv_round_trip() {
    let rs = wattlaw::tables::t9::rowset();
    let csv = rs.to_csv();
    assert!(
        csv.starts_with(
            "K,fleet,analyze tok/W (tok/J),simulate tok/W (tok/J),\
             delta (%),p99 TTFT (s),upgraded groups,\
             marginal tok/W (tok/J per group)\n"
        ),
        "t9 CSV header drifted:\n{}",
        csv.lines().next().unwrap_or("")
    );
    assert_eq!(csv.lines().count(), 1 + 6, "3 fleets × K in {{2, 3}}");

    let doc = parse_json(&rs.to_json()).expect("t9 emits valid JSON");
    let cols = doc.get("columns").unwrap().as_arr().unwrap();
    assert_eq!(cols.len(), 8);
    assert_eq!(cols[1].get("name").unwrap().as_str(), Some("fleet"));
    assert_eq!(
        cols[7].get("unit").unwrap().as_str(),
        Some("tok/J per group")
    );
    let rows = doc.get("rows").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 6);
    // Rows come in (floor, mixed, ceiling) triples per K: the all-H100
    // floor has no upgraded groups and a missing marginal; the others
    // carry both.
    for (i, r) in rows.iter().enumerate() {
        assert!(r.get("analyze tok/W").unwrap().as_f64().is_some());
        assert!(r.get("simulate tok/W").unwrap().as_f64().is_some());
        let upgraded = r.get("upgraded groups").unwrap().as_f64().unwrap();
        if i % 3 == 0 {
            assert_eq!(upgraded, 0.0, "row {i} is an all-H100 floor");
            assert_eq!(r.get("marginal tok/W"), Some(&Json::Null));
        } else {
            assert!(upgraded > 0.0, "row {i} upgrades groups");
            assert!(r.get("marginal tok/W").unwrap().as_f64().is_some());
        }
    }

    // The machine CSV survives the crate's own parser with the measured
    // column intact at full precision.
    let parsed = parse_csv(&csv).unwrap_or_else(|e| panic!("parse: {e}"));
    assert_eq!(parsed.len(), 1 + 6);
    let col = parsed[0]
        .iter()
        .position(|h| h.starts_with("simulate tok/W"))
        .expect("simulate column");
    for row in &parsed[1..] {
        assert_eq!(row.len(), 8, "t9 schema arity");
        let v: f64 = row[col].parse().expect("full-precision float");
        assert!(v > 0.0);
    }
}

/// A `simulate sweep` grid with a K=3 partition cell must round-trip
/// through the crate's own CSV parser (the CI artifact path).
#[test]
fn kpool_sweep_csv_round_trips_through_the_parser() {
    use wattlaw::fleet::topology::default_partition;
    use wattlaw::scenario::sweep::{grid, records, rowset, run, SweepConfig};
    use wattlaw::workload::cdf::azure_conversations;
    use wattlaw::workload::synth::GenConfig;

    let cfg = SweepConfig {
        gen: GenConfig {
            lambda_rps: 150.0,
            duration_s: 0.3,
            max_prompt_tokens: 20_000,
            max_output_tokens: 64,
            seed: 8,
        },
        groups: 4,
        dispatches: vec!["rr".into()],
        b_shorts: Vec::new(),
        partitions: vec![default_partition(3)],
        spill: None,
        ..Default::default()
    };
    let specs = grid(&azure_conversations(), &cfg);
    // Homogeneous baseline + the K=3 partition cell, one dispatch each.
    assert_eq!(specs.len(), 2);
    let out = run(&specs, 2);
    let recs = records(&specs, &out, cfg.acct);
    let rs = rowset(&recs, &cfg);
    let csv = rs.to_csv();
    assert!(csv.contains("3-pool"), "K-pool cell missing:\n{csv}");

    let parsed = parse_csv(&csv).unwrap_or_else(|e| panic!("parse: {e}"));
    assert_eq!(parsed.len(), 1 + recs.len());
    for row in &parsed {
        assert_eq!(row.len(), 12, "sweep schema arity");
    }
    // The measured tok/W column survives the round trip at full value.
    let col = parsed[0]
        .iter()
        .position(|h| h.starts_with("simulate tok/W"))
        .expect("simulate column");
    for (i, r) in recs.iter().enumerate() {
        let back: f64 = parsed[1 + i][col].parse().unwrap();
        assert_eq!(back.to_bits(), r.outcome.tok_per_watt.to_bits());
    }
}

/// Random printable-ish strings, including CSV-hostile characters.
fn random_string(rng: &mut Rng) -> String {
    const ALPHABET: &[char] = &[
        'a', 'B', '7', ' ', ',', '"', '\n', 'γ', 'λ', '/', '%', '-', '.',
    ];
    let len = rng.range_usize(0, 12);
    (0..len)
        .map(|_| ALPHABET[rng.range_usize(0, ALPHABET.len() - 1)])
        .collect()
}

fn random_float(rng: &mut Rng) -> f64 {
    // Mix of magnitudes and signs, all finite.
    let base = rng.f64() * 10f64.powi(rng.range_usize(0, 8) as i32 - 4);
    if rng.f64() < 0.5 {
        -base
    } else {
        base
    }
}

#[test]
fn prop_csv_round_trips_for_random_tables() {
    let mut rng = Rng::new(0xC5F);
    for case in 0..60 {
        let ncols = rng.range_usize(1, 5);
        let nrows = rng.range_usize(0, 12);
        let columns: Vec<Column> = (0..ncols)
            .map(|i| {
                let c = Column::str(format!("col{i}"));
                if rng.f64() < 0.4 {
                    c.with_unit(random_string(&mut rng))
                } else {
                    c
                }
            })
            .collect();
        let mut rs = RowSet::new(format!("random {case}"), columns);
        let mut expected: Vec<Vec<Value>> = Vec::new();
        for _ in 0..nrows {
            let mut row = Vec::new();
            let mut vals = Vec::new();
            for _ in 0..ncols {
                let cell = match rng.range_usize(0, 4) {
                    0 => Cell::str(random_string(&mut rng)),
                    1 => Cell::float(random_float(&mut rng)),
                    2 => Cell::int(rng.next_u64() as i64),
                    3 => Cell::bool(rng.f64() < 0.5),
                    _ => Cell::missing(),
                };
                vals.push(cell.value.clone());
                row.push(cell);
            }
            expected.push(vals);
            rs.push(row);
        }

        let parsed = parse_csv(&rs.to_csv())
            .unwrap_or_else(|e| panic!("case {case}: parse failed: {e}"));
        assert_eq!(parsed.len(), 1 + nrows, "case {case}: row count");
        assert_eq!(parsed[0].len(), ncols, "case {case}: header arity");
        for (ri, vals) in expected.iter().enumerate() {
            let got = &parsed[1 + ri];
            assert_eq!(got.len(), ncols, "case {case} row {ri}: arity");
            for (ci, v) in vals.iter().enumerate() {
                match v {
                    Value::Str(s) => assert_eq!(&got[ci], s, "case {case}"),
                    Value::Int(i) => {
                        assert_eq!(
                            got[ci].parse::<i64>().unwrap(),
                            *i,
                            "case {case}"
                        )
                    }
                    Value::Float(x) => {
                        // Rust's shortest Display round-trips exactly.
                        let back: f64 = got[ci].parse().unwrap();
                        assert_eq!(back.to_bits(), x.to_bits(), "case {case}");
                    }
                    Value::Bool(b) => {
                        assert_eq!(
                            got[ci].parse::<bool>().unwrap(),
                            *b,
                            "case {case}"
                        )
                    }
                    Value::Missing => {
                        assert!(got[ci].is_empty(), "case {case}")
                    }
                }
            }
        }

        // The JSON side of the same random table must parse too.
        parse_json(&rs.to_json())
            .unwrap_or_else(|e| panic!("case {case}: bad JSON: {e}"));
    }
}
