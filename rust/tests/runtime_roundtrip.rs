//! Real-runtime integration: the AOT artifacts through PJRT — golden
//! numerics, incremental-vs-prefill consistency, engine E2E, and a leak
//! regression guard.
//!
//! These tests need `make artifacts`; they skip (pass trivially with a
//! notice) when the artifacts directory is absent so `cargo test` works
//! on a fresh checkout.

use std::path::PathBuf;

use wattlaw::router::context::ContextRouter;
use wattlaw::runtime::TinyModel;
use wattlaw::serve::{serve_trace, EngineConfig, PoolSpec};
use wattlaw::workload::Request;

fn artifacts() -> Option<PathBuf> {
    let dir = wattlaw::runtime::default_artifacts_dir();
    if dir.join("decode_step.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts missing — run `make artifacts`; skipping");
        None
    }
}

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for l in s.lines() {
        if let Some(rest) = l.strip_prefix("VmRSS:") {
            return rest
                .split_whitespace()
                .next()
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(0.0)
                / 1024.0;
        }
    }
    0.0
}

#[test]
fn golden_numerics_match_jax() {
    let Some(dir) = artifacts() else { return };
    let model = TinyModel::load(&dir).unwrap();
    let err = model.validate_golden().unwrap();
    assert!(err < 1e-3, "max |err| = {err}");
}

#[test]
fn decode_continues_prefill_consistently() {
    // Feed the same tokens two ways: (a) prefill of length t, then decode
    // the token at position t; (b) prefill of length t+1. The last-step
    // logits must agree — the Rust-side version of the python
    // `test_decode_consistent_with_prefill` invariant, across the whole
    // AOT + PJRT + container stack.
    let Some(dir) = artifacts() else { return };
    let model = TinyModel::load(&dir).unwrap();
    let b = model.cfg.batch as usize;
    let t_pref = model.cfg.prefill_len as usize;
    let t = 6usize;

    let tokens: Vec<i32> = (0..b * t_pref).map(|i| (i % 29) as i32).collect();

    // (a): prefill t, decode token at position t.
    let lens_a = vec![t as i32; b];
    let (_, kv_k, kv_v) = model.prefill(&tokens, &lens_a).unwrap();
    let next: Vec<i32> =
        (0..b).map(|r| tokens[r * t_pref + t]).collect();
    let pos = vec![t as i32; b];
    let (logits_a, _, _) = model.decode_step(&next, &kv_k, &kv_v, &pos).unwrap();

    // (b): prefill t+1 directly.
    let lens_b = vec![(t + 1) as i32; b];
    let (logits_b, _, _) = model.prefill(&tokens, &lens_b).unwrap();

    let max_err = logits_a
        .iter()
        .zip(&logits_b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 2e-3, "incremental vs full prefill: {max_err}");
}

#[test]
fn greedy_decode_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let model = TinyModel::load(&dir).unwrap();
    let b = model.cfg.batch as usize;
    let run = || {
        let (mut kv_k, mut kv_v) = model.fresh_kv().unwrap();
        let mut tok = vec![5i32; b];
        let mut pos = vec![0i32; b];
        let mut out = Vec::new();
        for _ in 0..6 {
            let (logits, k, v) =
                model.decode_step(&tok, &kv_k, &kv_v, &pos).unwrap();
            kv_k = k;
            kv_v = v;
            tok = model.argmax(&logits);
            out.extend(tok.clone());
            for p in &mut pos {
                *p += 1;
            }
        }
        out
    };
    assert_eq!(run(), run());
}

#[test]
fn engine_serves_real_requests_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let reqs: Vec<Request> = (0..8)
        .map(|id| Request {
            id,
            arrival_s: 0.0,
            prompt_tokens: 16 + 8 * id as u32,
            output_tokens: 6,
        })
        .collect();
    let pools = vec![
        PoolSpec {
            name: "short".into(),
            config: EngineConfig::for_window(128, 16)
                .with_ingest_slots(8)
                .emulating_h100(4096),
        },
        PoolSpec {
            name: "long".into(),
            config: EngineConfig::for_window(480, 16)
                .with_ingest_slots(8)
                .emulating_h100(65_536),
        },
    ];
    let report =
        serve_trace(&dir, &ContextRouter::two_pool(128), &pools, &reqs).unwrap();
    let done: u64 = report.pools.iter().map(|p| p.metrics.completed).sum();
    assert_eq!(done, 8);
    assert_eq!(report.total_output_tokens, 8 * 6);
    assert!(report.tok_per_watt > 0.0);
    assert!(report.golden_max_err < 1e-3);
}

#[test]
fn decode_loop_does_not_leak() {
    // Regression guard for the execute()-input leak (~45 MB/step before
    // the owned-buffer fix): 40 steps must not grow RSS by >400 MB.
    let Some(dir) = artifacts() else { return };
    let model = TinyModel::load(&dir).unwrap();
    let b = model.cfg.batch as usize;
    let (mut kv_k, mut kv_v) = model.fresh_kv().unwrap();
    let tok = vec![1i32; b];
    let mut pos = vec![0i32; b];

    // Warm up allocator pools.
    for _ in 0..5 {
        let (_, k, v) = model.decode_step(&tok, &kv_k, &kv_v, &pos).unwrap();
        kv_k = k;
        kv_v = v;
        for p in &mut pos {
            *p += 1;
        }
    }
    let before = rss_mb();
    for _ in 0..40 {
        let (_, k, v) = model.decode_step(&tok, &kv_k, &kv_v, &pos).unwrap();
        kv_k = k;
        kv_v = v;
        for p in &mut pos {
            *p += 1;
        }
    }
    let grown = rss_mb() - before;
    assert!(grown < 400.0, "RSS grew {grown:.0} MB over 40 steps");
}
