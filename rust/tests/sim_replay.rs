//! Deterministic-replay guarantee of the event-driven simulation core.
//!
//! The pre-refactor simulator dispatched round-robin at arrival and ran
//! each group as an isolated sequential loop. That loop is preserved
//! below (`legacy` module) as the oracle: under round-robin dispatch the
//! event engine must reproduce its `output_tokens` and `joules`
//! **bit-for-bit** on the seeded Azure trace — same float operations in
//! the same order, just re-sequenced through the shared event heap.
//!
//! Also here: the parallel fast path must match the sequential engine
//! exactly, and join-shortest-queue must strictly beat round-robin on a
//! bursty, size-skewed two-pool trace (the behavioral payoff the
//! refactor exists to make expressible).
//!
//! The streaming arrival engine rides the same contract: pulling the
//! bursty trace through a [`VecSource`] one request at a time must
//! replay the materialized run bit-for-bit, and a constant-rate
//! generator source must push the engine through 2×10⁵ (and, ignored
//! by default, 10⁷) arrivals while only ever holding one pending
//! arrival in memory — sequentially and through the sharded per-group
//! demux — and, under the default fused macro-stepping, while popping
//! O(arrivals) events rather than O(decode steps). A
//! hand-built trace whose second arrival lands *exactly* on the fused
//! horizon pins the boundary tie-break against the per-step oracle.

use wattlaw::router::context::ContextRouter;
use wattlaw::router::HomogeneousRouter;
use wattlaw::sim::dispatch::{JoinShortestQueue, RoundRobin};
use wattlaw::sim::{
    simulate_topology, simulate_topology_opts, simulate_topology_with,
    EngineOptions, GroupSimConfig, StateMode,
};
use wattlaw::workload::synth::{generate, GenConfig};
use wattlaw::workload::Request;

/// The pre-refactor sequential simulator, verbatim (round-robin at
/// arrival, isolated per-group closed loops).
mod legacy {
    use wattlaw::router::Router;
    use wattlaw::serve::batcher::{Batcher, SlotWork};
    use wattlaw::serve::energy::EnergyMeter;
    use wattlaw::serve::kvblocks::BlockAllocator;
    use wattlaw::serve::metrics::ServeMetrics;
    use wattlaw::serve::request::ServeRequest;
    use wattlaw::sim::GroupSimConfig;
    use wattlaw::workload::Request;

    pub struct PoolResult {
        pub metrics: ServeMetrics,
        pub output_tokens: u64,
        pub joules: f64,
    }

    pub struct TopoResult {
        pub pools: Vec<PoolResult>,
        pub output_tokens: u64,
        pub joules: f64,
    }

    struct GroupResult {
        metrics: ServeMetrics,
        joules: f64,
        output_tokens: u64,
    }

    fn simulate_group(arrivals: Vec<ServeRequest>, cfg: &GroupSimConfig) -> GroupResult {
        let blocks_total =
            (cfg.n_max as u64 * cfg.window_tokens as u64 / 64).max(1) as u32;
        let mut b = Batcher::new(
            cfg.n_max as usize,
            BlockAllocator::new(64, blocks_total),
            cfg.ingest_chunk,
            cfg.window_tokens,
        );
        let mut meter = EnergyMeter::new(cfg.power, cfg.gpus_charged, 0.0);
        let mut metrics = ServeMetrics::default();

        let mut pending = arrivals.into_iter().peekable();
        let mut t = 0.0f64;

        loop {
            while pending.peek().map(|r| r.arrival_s <= t).unwrap_or(false) {
                let r = pending.next().unwrap();
                if !b.submit(r) {
                    metrics.rejected += 1;
                }
            }
            b.admit(t);

            if b.active() == 0 {
                match pending.peek() {
                    Some(r) => {
                        let t_next = r.arrival_s;
                        meter.observe(t_next, 0.0);
                        t = t_next;
                        continue;
                    }
                    None => break,
                }
            }

            let plan = b.plan();
            let n_active = plan
                .iter()
                .filter(|w| !matches!(w, SlotWork::Idle))
                .count() as f64;
            let l_bar = b.mean_kv_len().max(1.0);
            let dt = cfg.roofline.tau_ms(n_active, l_bar) / 1e3;
            t += dt;
            meter.observe(t, n_active);

            for (i, w) in plan.into_iter().enumerate() {
                match w {
                    SlotWork::Idle => {}
                    SlotWork::Ingest { .. } => {
                        b.on_step(i, w, t);
                    }
                    SlotWork::Decode => {
                        meter.add_output_tokens(1);
                        if let Some(c) = b.on_step(i, SlotWork::Decode, t) {
                            metrics.record(&c);
                        }
                    }
                }
            }
        }

        GroupResult {
            metrics,
            joules: meter.joules().0,
            output_tokens: meter.output_tokens(),
        }
    }

    pub fn simulate_pool(
        mut requests: Vec<ServeRequest>,
        groups: u32,
        cfg: &GroupSimConfig,
    ) -> PoolResult {
        assert!(groups > 0);
        requests.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));

        let mut per_group: Vec<Vec<ServeRequest>> =
            vec![Vec::new(); groups as usize];
        for (i, r) in requests.into_iter().enumerate() {
            per_group[i % groups as usize].push(r);
        }

        let mut metrics = ServeMetrics::default();
        let mut joules = 0.0;
        let mut output_tokens = 0u64;
        for arrivals in per_group {
            let g = simulate_group(arrivals, cfg);
            metrics.merge(&g.metrics);
            joules += g.joules;
            output_tokens += g.output_tokens;
        }
        PoolResult { metrics, output_tokens, joules }
    }

    pub fn simulate_topology(
        trace: &[Request],
        router: &dyn Router,
        pool_groups: &[u32],
        pool_cfgs: &[GroupSimConfig],
    ) -> TopoResult {
        let mut per_pool: Vec<Vec<ServeRequest>> =
            vec![Vec::new(); pool_cfgs.len()];
        for req in trace {
            let route = router.route(req);
            let mut s = ServeRequest::from(req);
            s.prompt_tokens = route.effective_prompt_tokens;
            per_pool[route.pool].push(s);
        }
        let pools: Vec<PoolResult> = per_pool
            .into_iter()
            .enumerate()
            .map(|(i, reqs)| simulate_pool(reqs, pool_groups[i], &pool_cfgs[i]))
            .collect();
        let output_tokens = pools.iter().map(|p| p.output_tokens).sum();
        let joules: f64 = pools.iter().map(|p| p.joules).sum();
        TopoResult { pools, output_tokens, joules }
    }
}

fn h100_cfg(window: u32) -> GroupSimConfig {
    use wattlaw::fleet::profile::{GpuProfile, ManualProfile};
    let p = ManualProfile::h100_70b();
    GroupSimConfig {
        window_tokens: window,
        n_max: p.n_max(window),
        roofline: p.roofline(),
        power: p.gpu().power,
        gpus_charged: 1.0,
        ingest_chunk: 1024,
    }
}

fn seeded_azure_trace() -> Vec<Request> {
    generate(
        &wattlaw::workload::cdf::azure_conversations(),
        &GenConfig {
            lambda_rps: 40.0,
            duration_s: 5.0,
            max_prompt_tokens: 60_000,
            max_output_tokens: 1024,
            seed: 42,
        },
    )
}

#[test]
fn event_engine_replays_legacy_bit_for_bit_homogeneous() {
    let trace = seeded_azure_trace();
    let old = legacy::simulate_topology(
        &trace,
        &HomogeneousRouter,
        &[4],
        &[h100_cfg(65_536)],
    );
    let new = simulate_topology(&trace, &HomogeneousRouter, &[4], &[h100_cfg(65_536)]);
    assert_eq!(new.output_tokens, old.output_tokens);
    assert_eq!(
        new.joules.to_bits(),
        old.joules.to_bits(),
        "joules must replay bit-for-bit: {} vs {}",
        new.joules,
        old.joules
    );
    let done: u64 = new.pools.iter().map(|p| p.metrics.completed).sum();
    let done_old: u64 = old.pools.iter().map(|p| p.metrics.completed).sum();
    assert_eq!(done, done_old);
}

#[test]
fn event_engine_replays_legacy_bit_for_bit_two_pool() {
    let trace = seeded_azure_trace();
    let router = ContextRouter::two_pool(4096);
    let groups = [2u32, 2];
    let cfgs = [h100_cfg(4096 + 1024), h100_cfg(65_536)];
    let old = legacy::simulate_topology(&trace, &router, &groups, &cfgs);
    let new = simulate_topology(&trace, &router, &groups, &cfgs);
    assert_eq!(new.output_tokens, old.output_tokens);
    assert_eq!(new.joules.to_bits(), old.joules.to_bits());
    for (np, op) in new.pools.iter().zip(&old.pools) {
        assert_eq!(np.output_tokens, op.output_tokens, "{}", np.name);
        assert_eq!(np.joules.to_bits(), op.joules.to_bits(), "{}", np.name);
        assert_eq!(np.metrics.completed, op.metrics.completed, "{}", np.name);
        assert_eq!(np.metrics.rejected, op.metrics.rejected, "{}", np.name);
    }
}

#[test]
fn parallel_fast_path_matches_sequential_engine_bit_for_bit() {
    let trace = seeded_azure_trace();
    let router = ContextRouter::two_pool(4096);
    let groups = [2u32, 2];
    let cfgs = [h100_cfg(4096 + 1024), h100_cfg(65_536)];
    let mut rr_seq = RoundRobin::new();
    let seq =
        simulate_topology_with(&trace, &router, &groups, &cfgs, &mut rr_seq, false);
    let mut rr_par = RoundRobin::new();
    let par =
        simulate_topology_with(&trace, &router, &groups, &cfgs, &mut rr_par, true);
    assert_eq!(seq.output_tokens, par.output_tokens);
    assert_eq!(seq.joules.to_bits(), par.joules.to_bits());
    assert_eq!(seq.steps, par.steps);
    for (s, p) in seq.pools.iter().zip(&par.pools) {
        assert_eq!(s.joules.to_bits(), p.joules.to_bits());
        assert_eq!(s.horizon_s.to_bits(), p.horizon_s.to_bits());
        assert_eq!(s.mean_batch.to_bits(), p.mean_batch.to_bits());
    }
}

/// A bursty, size-skewed two-pool trace where round-robin's parity
/// assignment is pathological: short-pool requests arrive in
/// (tiny, huge) pairs, so round-robin pins every huge-output request to
/// the same group — one group saturates with backlog while its sibling
/// trickles at batch ≈ 1, burning near-idle watts per token. JSQ sees
/// the skew in the queue depths and rebalances, so both groups run hot.
fn bursty_two_pool_trace() -> Vec<Request> {
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for i in 0..240u64 {
        let t = i as f64 * 0.25;
        reqs.push(Request {
            id: { id += 1; id },
            arrival_s: t,
            prompt_tokens: 64,
            output_tokens: 30, // tiny
        });
        reqs.push(Request {
            id: { id += 1; id },
            arrival_s: t + 0.001,
            prompt_tokens: 64,
            output_tokens: 500, // huge
        });
    }
    // A thin long-context stream keeps the second pool genuinely active.
    for i in 0..20u64 {
        reqs.push(Request {
            id: { id += 1; id },
            arrival_s: i as f64 * 3.0,
            prompt_tokens: 20_000,
            output_tokens: 100,
        });
    }
    reqs
}

#[test]
fn jsq_strictly_beats_round_robin_on_bursty_two_pool_trace() {
    let trace = bursty_two_pool_trace();
    let router = ContextRouter::two_pool(4096);
    let groups = [2u32, 2];
    // Small n_max on the short pool so saturation and queueing are real.
    let mut short = h100_cfg(4096 + 1024);
    short.n_max = 8;
    let cfgs = [short, h100_cfg(65_536)];

    let mut rr = RoundRobin::new();
    let rr_report =
        simulate_topology_with(&trace, &router, &groups, &cfgs, &mut rr, true);
    let mut jsq = JoinShortestQueue;
    let jsq_report =
        simulate_topology_with(&trace, &router, &groups, &cfgs, &mut jsq, true);

    // Same work either way…
    assert_eq!(rr_report.output_tokens, jsq_report.output_tokens);
    // …but strictly better energy efficiency under load-aware dispatch.
    assert!(
        jsq_report.tok_per_watt > rr_report.tok_per_watt * 1.02,
        "JSQ must strictly improve tok/W: jsq = {:.4}, rr = {:.4}",
        jsq_report.tok_per_watt,
        rr_report.tok_per_watt
    );
}

/// The incremental-state refactor's replay guarantee: the in-place live
/// FleetState must drive exactly the same routing/dispatch decisions as
/// the pre-refactor rebuild-a-snapshot-per-arrival engine — joules
/// bit-for-bit — and survive the engine's per-event cross-check against
/// a freshly built snapshot.
#[test]
fn incremental_live_state_replays_rebuild_per_arrival_bit_for_bit() {
    let trace = bursty_two_pool_trace();
    let router = ContextRouter::two_pool(4096);
    let groups = [2u32, 2];
    let mut short = h100_cfg(4096 + 1024);
    short.n_max = 8;
    let cfgs = [short, h100_cfg(65_536)];

    let run = |mode: StateMode, validate: bool| {
        let mut jsq = JoinShortestQueue;
        simulate_topology_opts(
            &trace,
            &router,
            &groups,
            &cfgs,
            &mut jsq,
            EngineOptions {
                allow_parallel: false,
                state_mode: mode,
                validate_state: validate,
                ..Default::default()
            },
        )
    };
    let incremental = run(StateMode::Incremental, true);
    let rebuilt = run(StateMode::RebuildPerArrival, false);

    assert_eq!(incremental.output_tokens, rebuilt.output_tokens);
    assert_eq!(
        incremental.joules.to_bits(),
        rebuilt.joules.to_bits(),
        "live-state joules must replay the snapshot oracle bit-for-bit: \
         {} vs {}",
        incremental.joules,
        rebuilt.joules
    );
    assert_eq!(incremental.steps, rebuilt.steps);
    for (a, b) in incremental.pools.iter().zip(&rebuilt.pools) {
        assert_eq!(a.joules.to_bits(), b.joules.to_bits(), "{}", a.name);
        assert_eq!(a.horizon_s.to_bits(), b.horizon_s.to_bits(), "{}", a.name);
        assert_eq!(a.metrics.completed, b.metrics.completed, "{}", a.name);
        assert_eq!(a.metrics.rejected, b.metrics.rejected, "{}", a.name);
    }
}

/// The streaming engine's replay guarantee on a hand-built trace: the
/// bursty two-pool workload pulled through a [`VecSource`] one request
/// at a time — under a load-aware dispatch policy, where every queue
/// depth the policy reads depends on event order — must match the
/// materialized engine bit-for-bit.
#[test]
fn streamed_vec_source_replays_bursty_trace_bit_for_bit() {
    use wattlaw::sim::simulate_topology_source;
    use wattlaw::workload::VecSource;

    // The streaming source contract is non-decreasing arrival times
    // (the materialized path sorts internally; a source has no trace
    // to sort).
    let mut trace = bursty_two_pool_trace();
    trace.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    let router = ContextRouter::two_pool(4096);
    let groups = [2u32, 2];
    let mut short = h100_cfg(4096 + 1024);
    short.n_max = 8;
    let cfgs = [short, h100_cfg(65_536)];
    let opts = EngineOptions { allow_parallel: false, ..Default::default() };

    let mut jsq = JoinShortestQueue;
    let mat =
        simulate_topology_opts(&trace, &router, &groups, &cfgs, &mut jsq, opts);
    let mut jsq = JoinShortestQueue;
    let mut src = VecSource::new(trace.clone());
    let stream = simulate_topology_source(
        &mut src, &router, &groups, &cfgs, &mut jsq, opts,
    );

    assert_eq!(stream.output_tokens, mat.output_tokens);
    assert_eq!(
        stream.joules.to_bits(),
        mat.joules.to_bits(),
        "streamed joules must replay the materialized run bit-for-bit: \
         {} vs {}",
        stream.joules,
        mat.joules
    );
    assert_eq!(stream.steps, mat.steps);
    assert_eq!(stream.idle_joules.to_bits(), mat.idle_joules.to_bits());
    for (s, m) in stream.pools.iter().zip(&mat.pools) {
        assert_eq!(s.joules.to_bits(), m.joules.to_bits(), "{}", s.name);
        assert_eq!(s.horizon_s.to_bits(), m.horizon_s.to_bits(), "{}", s.name);
        assert_eq!(s.metrics.completed, m.metrics.completed, "{}", s.name);
        assert_eq!(s.metrics.rejected, m.metrics.rejected, "{}", s.name);
    }
}

/// A constant-rate metronome generating requests on the fly: the
/// streaming engine's O(1)-memory counterexample to "a trace is a
/// Vec". Holds no backing storage at all — every [`Request`] is minted
/// inside `next()`.
struct ConstSource {
    n: u64,
    i: u64,
    gap: f64,
}

impl Iterator for ConstSource {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.i == self.n {
            return None;
        }
        self.i += 1;
        Some(Request {
            id: self.i,
            arrival_s: self.i as f64 * self.gap,
            prompt_tokens: 32,
            output_tokens: 1,
        })
    }
}

impl wattlaw::workload::ArrivalSource for ConstSource {
    fn gap_hint(&self) -> f64 {
        self.gap
    }
}

fn run_const_source(n: u64, allow_parallel: bool) {
    use wattlaw::sim::simulate_topology_source;

    let mut src = ConstSource { n, i: 0, gap: 0.25 };
    let mut rr = RoundRobin::new();
    // With `allow_parallel` this constant-rate scenario (static router
    // and dispatch, two groups) takes the sharded streaming path: a
    // demux thread routing each minted request to its group's worker
    // over a bounded channel — still O(1) trace memory end to end.
    let report = simulate_topology_source(
        &mut src,
        &HomogeneousRouter,
        &[2],
        &[h100_cfg(8192)],
        &mut rr,
        EngineOptions { allow_parallel, ..Default::default() },
    );
    let completed: u64 = report.pools.iter().map(|p| p.metrics.completed).sum();
    let rejected: u64 = report.pools.iter().map(|p| p.metrics.rejected).sum();
    assert_eq!(completed, n, "every generated arrival must complete");
    assert_eq!(rejected, 0);
    // One decode token per request: exact token conservation.
    assert_eq!(report.output_tokens, n);
    // Under the default fused macro-stepping the widely spaced requests
    // run ingest + decode in-line (every step ends long before the next
    // arrival), so the only real events are the arrival itself and at
    // most one wake/step per request — a hard O(arrivals) ceiling,
    // independent of how many decode steps each request takes.
    assert!(
        report.events_popped <= 3 * n + 16,
        "fused engine must pop O(arrivals) events: popped {} for {n} \
         arrivals",
        report.events_popped
    );
}

#[test]
fn streamed_engine_completes_two_hundred_thousand_generated_arrivals() {
    run_const_source(200_000, false);
}

#[test]
fn sharded_stream_completes_two_hundred_thousand_generated_arrivals() {
    run_const_source(200_000, true);
}

/// The acceptance-scale smoke: materialized, this trace would be
/// 10⁷ × `size_of::<Request>()` ≈ 240 MB before the engine ran a
/// single event; streamed, exactly one pending arrival exists at any
/// moment regardless of `n` — and fused macro-stepping (the default
/// inside [`run_const_source`]) keeps total events popped under a hard
/// 3n + 16 ceiling, so the event count provably scales with arrivals.
#[test]
#[ignore = "10^7 arrivals — minutes of runtime; run explicitly"]
fn streamed_engine_holds_ten_million_arrivals_in_constant_memory() {
    run_const_source(10_000_000, false);
}

/// Same acceptance scale through the sharded demux: 10⁷ arrivals flow
/// demux → bounded per-group channels → two group workers, with at most
/// `groups × buffer` requests in flight at any moment — constant memory
/// in `n` — and the per-group event totals stay under the same
/// 3n + 16 fused ceiling.
#[test]
#[ignore = "10^7 arrivals — minutes of runtime; run explicitly"]
fn sharded_stream_holds_ten_million_arrivals_in_constant_memory() {
    run_const_source(10_000_000, true);
}

/// Boundary tie-break: an arrival landing *exactly* on the fused
/// horizon (bit-equal `f64` timestamps) must not be skipped past. The
/// fusion test is a strict `t_end < next_arrival`, so the step whose
/// end coincides with the arrival falls back to a real `StepComplete`
/// event — and the event order (arrival class before step class at
/// equal time) is then identical to per-step mode, floats and all.
#[test]
fn arrival_exactly_on_fused_horizon_replays_per_step_bitwise() {
    use wattlaw::sim::StepMode;

    let groups = [1u32];
    let cfgs = [h100_cfg(8192)];
    let first = Request {
        id: 1,
        arrival_s: 0.0,
        prompt_tokens: 512,
        output_tokens: 40,
    };
    // Probe run: with a single request, the pool horizon is the exact
    // t_end of its final decode step. Arriving a second request at that
    // bit-identical timestamp lands it on the fused horizon boundary.
    let mut rr = RoundRobin::new();
    let probe = simulate_topology_opts(
        &[first.clone()],
        &HomogeneousRouter,
        &groups,
        &cfgs,
        &mut rr,
        EngineOptions { allow_parallel: false, ..Default::default() },
    );
    let boundary = probe.pools[0].horizon_s;
    assert!(boundary > 0.0 && boundary.is_finite());

    let trace = vec![
        first,
        Request {
            id: 2,
            arrival_s: boundary,
            prompt_tokens: 512,
            output_tokens: 40,
        },
    ];
    let run = |step_mode: StepMode| {
        let mut rr = RoundRobin::new();
        simulate_topology_opts(
            &trace,
            &HomogeneousRouter,
            &groups,
            &cfgs,
            &mut rr,
            EngineOptions {
                allow_parallel: false,
                step_mode,
                ..Default::default()
            },
        )
    };
    let fused = run(StepMode::Fused);
    let oracle = run(StepMode::PerStep);

    assert!(
        fused.events_popped < oracle.events_popped,
        "fused must still pop fewer events overall: {} vs {}",
        fused.events_popped,
        oracle.events_popped
    );
    let completed: u64 =
        fused.pools.iter().map(|p| p.metrics.completed).sum();
    assert_eq!(completed, 2, "the boundary arrival must be served");
    assert_eq!(fused.output_tokens, oracle.output_tokens);
    assert_eq!(
        fused.joules.to_bits(),
        oracle.joules.to_bits(),
        "boundary-tie joules must replay bit-for-bit: {} vs {}",
        fused.joules,
        oracle.joules
    );
    assert_eq!(fused.steps, oracle.steps);
    assert_eq!(fused.idle_joules.to_bits(), oracle.idle_joules.to_bits());
    for (f, o) in fused.pools.iter().zip(&oracle.pools) {
        assert_eq!(f.horizon_s.to_bits(), o.horizon_s.to_bits(), "{}", f.name);
        assert_eq!(f.joules.to_bits(), o.joules.to_bits(), "{}", f.name);
        assert_eq!(f.metrics.completed, o.metrics.completed, "{}", f.name);
    }
}
